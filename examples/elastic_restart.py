"""Fault-tolerance + elasticity example: train with SASG on a 4-worker mesh,
kill the run mid-flight (simulated node failure), then resume the SAME
checkpoint on a DIFFERENT mesh layout (2-pod hierarchical) — parameters carry
over exactly; SASG error-feedback state re-initializes per DESIGN.md §5.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import sasg_config
from repro.data import token_stream
from repro.dist.strategy import Strategy, choose_strategy
from repro.launch.mesh import make_test_mesh
from repro.models import build
from repro.optim import constant
from repro.train import Trainer, TrainerConfig, build_train_step


def main():
    cfg = get_config("starcoder2_3b").reduced()
    model = build(cfg)
    scfg = sasg_config(k_ratio=0.02, max_delay=5)
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)

    def data():
        for b in stream:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    ckpt = tempfile.mkdtemp(prefix="sasg_ckpt_")

    # phase 1: flat 4-worker mesh; a fault fires at step 7 and the Trainer
    # recovers from the last checkpoint automatically
    mesh1 = make_test_mesh((4, 2), ("data", "model"))
    strat1 = Strategy("flat", ("data",), ("data",), None, None, "model", 4)
    built1 = build_train_step(model, scfg, mesh1, strat1, constant(0.05))
    boom = {7}

    def fault(step):
        if step in boom:
            boom.discard(step)
            raise RuntimeError("simulated node failure")

    tr1 = Trainer(built1, data(),
                  TrainerConfig(total_steps=12, ckpt_dir=ckpt, ckpt_every=3,
                                log_every=3, ckpt_async=False),
                  fault_hook=fault)
    tr1.run(init_key=jax.random.PRNGKey(0))
    print("\n-- phase 1 done (survived 1 injected failure); resizing mesh --\n")

    # phase 2: resume the checkpoint on a 2-pod hierarchical mesh (elastic
    # resize: 4 flat workers -> 2 pod workers)
    mesh2 = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    strat2 = choose_strategy(mesh2, sasg_enabled=True)
    built2 = build_train_step(model, scfg, mesh2, strat2, constant(0.05))
    tr2 = Trainer(built2, data(),
                  TrainerConfig(total_steps=20, ckpt_dir=ckpt, ckpt_every=5,
                                log_every=4, ckpt_async=False))
    state = tr2.run(init_key=jax.random.PRNGKey(1))
    print(f"\nresumed on {strat2.name} mesh and reached step 20 "
          f"(loss {tr2.history[-1]['loss']:.4f})")


if __name__ == "__main__":
    main()

"""Quickstart: train a reduced LLaMA-3-family model with SASG on a 4x2
device mesh (8 fake CPU devices), watching the adaptive rule skip uploads.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import sasg_config
from repro.data import token_stream
from repro.dist.strategy import choose_strategy
from repro.launch.mesh import make_test_mesh
from repro.models import build
from repro.optim import constant
from repro.train import build_train_step


def main():
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)

    mesh = make_test_mesh((4, 2), ("data", "model"))
    strategy = choose_strategy(mesh, sasg_enabled=True)
    print(f"strategy: {strategy.name} ({strategy.num_workers} SASG workers, "
          f"TP over '{strategy.tp_axis}')")

    built = build_train_step(
        model,
        sasg_config(k_ratio=0.01, max_delay=10),   # paper: top-1%, D=10
        mesh, strategy, constant(0.05),
    )
    state = built.init(jax.random.PRNGKey(0))

    stream = token_stream(cfg.vocab_size, batch=8, seq=64, seed=0)
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        state, mets = built.jit_step(state, batch)
        if step % 5 == 0:
            print(f"step {step:3d}  loss {float(mets['loss']):7.4f}  "
                  f"uploads {float(mets['num_sent']):.0f}/{strategy.num_workers}  "
                  f"cum-bits(paper) {float(mets['bits_paper_total']):.3e}")
    dense_bits = 40 * strategy.num_workers * 32.0 * sum(
        x.size for x in jax.tree.leaves(state.params)
    )
    print(f"\nSASG transmitted {float(state.counters.bits_paper):.3e} bits; "
          f"dense SGD would have transmitted {dense_bits:.3e} "
          f"({dense_bits / float(state.counters.bits_paper):.0f}x more)")


if __name__ == "__main__":
    main()

"""Serving example: batched continuous decoding of a reduced InternVL2
language backbone on a 4x2 mesh — the decode path the decode_32k/long_500k
dry-run shapes lower at production scale.

  PYTHONPATH=src python examples/serve_batch.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import build
from repro.serve import BatchedServer, Request, build_serve


def main():
    cfg = get_config("internvl2_2b").reduced()
    model = build(cfg)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    serve = build_serve(model, mesh, fsdp="data", tp="model")
    params = jax.jit(model.init, out_shardings=serve.param_shardings)(
        jax.random.PRNGKey(0)
    )

    srv = BatchedServer(serve, params, cfg, batch_size=4, max_seq=128)
    rng = np.random.default_rng(0)
    for i in range(10):
        srv.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 9))).astype(np.int32),
            max_new_tokens=8,
        ))
    done, pending = srv.drain(strict=True)
    stats = srv.cache_stats()
    mode = "paged" if srv.paged else "dense"
    print(f"served {len(done)} requests in continuous batches of {srv.batch} "
          f"({mode} KV cache)")
    for r in sorted(done, key=lambda r: r["uid"])[:5]:
        print(f"  request {r['uid']}: generated {r['tokens']}")
    assert len(done) == 10 and not pending


if __name__ == "__main__":
    main()

"""Paper reproduction (Section 5): the four algorithms — SGD, Sparse, LASG,
SASG — on the paper's FC/MNIST setting (M=10 workers, 10 samples each,
top-1%, D=10), reporting rounds & bits to equal accuracy (Table 2) and the
accuracy-vs-rounds curves (Fig. 2).

  PYTHONPATH=src python examples/paper_repro.py
"""
import sys

sys.path.insert(0, "src")

from benchmarks.table2_rounds_bits import run


def main():
    results = run(quick=True)
    t2 = results["table2"]["fc_mnist"]
    print("\n== paper Table-2-style summary (synthetic-MNIST, FC-512) ==")
    print(f"{'method':8s} {'#rounds':>9s} {'#bits':>12s}   (to target accuracy)")
    for algo in ("sgd", "sparse", "lasg", "sasg"):
        r = t2[algo]
        print(f"{algo:8s} {r['rounds_to_target']:9.0f} {r['bits_to_target']:12.3e}")
    sgd, sasg = t2["sgd"], t2["sasg"]
    print(f"\nSASG vs SGD: {sgd['rounds_to_target']/max(sasg['rounds_to_target'],1):.1f}x "
          f"fewer rounds, {sgd['bits_to_target']/max(sasg['bits_to_target'],1):.0f}x fewer bits")


if __name__ == "__main__":
    main()

"""Paper Table 1: per-iteration communication rounds/bits cost model, plus
this framework's realized per-upload bits for the production archs."""
from __future__ import annotations

import jax

from repro.core.metrics import CommModel


def run(log=print):
    log("== Table 1: communication cost model (d-dim model, M workers) ==")
    m = CommModel(d=11_173_962, k=111_740, M=10)  # ResNet18-scale, top-1%
    log(f"{'method':8s} {'#rounds/iter':>14s} {'#bits/upload':>14s} {'total(T=100, sum|M^t|=600)':>28s}")
    rows = [
        ("sgd", m.M, 32 * m.d, m.total_bits("sgd", 100)),
        ("sparse", m.M, 32 * m.k, m.total_bits("sparse", 100)),
        ("lasg", "|M^t|", 32 * m.d, m.total_bits("lasg", 100, 600)),
        ("sasg", "|M^t|", 32 * m.k, m.total_bits("sasg", 100, 600)),
    ]
    out = []
    for name, rounds, bits, total in rows:
        log(f"{name:8s} {str(rounds):>14s} {bits:>14.3e} {total:>28.3e}")
        out.append({"method": name, "bits_per_upload": bits, "total_bits": total})
    # consistency: SASG saves both factors
    assert out[3]["total_bits"] < out[1]["total_bits"] < out[0]["total_bits"]
    assert out[3]["total_bits"] < out[2]["total_bits"]
    log("ok: SASG < {Sparse, LASG} < SGD\n")
    return {"table1": out}


if __name__ == "__main__":
    run()

"""Continuous-batching serve benchmark (BENCH_serve.json).

For each serveable arch family — global-attention LMs (dense AND paged KV
cache), SSD and RG-LRU recurrent LMs (dense state, O(1) per slot; nothing
to page) — runs the reduced config through the BatchedServer at a sweep of
concurrency levels on fake CPU devices and records tokens/s, tick counts,
and the cache-memory accounting (pool high-water vs the dense-equivalent
cache). Every paged cell replays the identical request stream against the
dense engine and records whether the generated tokens are bit-identical
(``bitexact_vs_dense`` — they must be on the identity cache dtype; the
``repro.analysis --check`` gate fails otherwise, same pattern as the
pipeline ring-bits ceiling). Run via

  PYTHONPATH=src python -m benchmarks.run --serve [--smoke]
"""
from __future__ import annotations

import json
import time

# archs benched per family; paged mode only exists for the global-attention
# rows (the recurrent families keep O(1) dense state)
ATTN_ARCHS = ("llama3_8b", "internvl2_2b", "starcoder2_3b")
RECURRENT_ARCHS = ("mamba2_370m", "recurrentgemma_9b")

NOTE = (
    "CPU fake-device timing: relative throughput only. Paged cells replay "
    "the same request stream as their dense twin; bitexact_vs_dense must "
    "hold on the identity cache dtype (analysis --check gates on it, and "
    "on high_water_bytes <= dense_equiv_bytes). Cache wire dtypes narrower "
    "than f32 (cache_dtype=bfloat16) are functional and covered by the "
    "parity-tolerance test, but are NOT timed here: on CPU XLA hoists the "
    "decode-side bf16->f32 convert out of the loop and re-materializes the "
    "full cache at f32, so a bf16 timing row would claim a memory saving "
    "the lowered CPU executable does not realize. f32-only rows until the "
    "accelerator backend lands."
)


def _run_server(srv, requests):
    for r in requests:
        srv.submit(r)
    t0 = time.perf_counter()
    done, pending = srv.drain(strict=True)
    dt = time.perf_counter() - t0
    assert not pending
    stats = srv.cache_stats()
    stats["wall_s"] = dt
    stats["tok_per_s"] = stats["decode_tokens"] / max(dt, 1e-9)
    return {r["uid"]: r["tokens"] for r in done}, stats


def run(smoke: bool = False, out_path: str = "BENCH_serve.json") -> dict:
    import jax
    import numpy as np

    import repro.compat
    from repro.configs import get_config
    from repro.models import build
    from repro.serve import BatchedServer, Request, build_serve

    mesh = repro.compat.make_mesh((2, 2), ("data", "model"))
    archs = ("internvl2_2b",) if smoke else ATTN_ARCHS + RECURRENT_ARCHS
    concurrency = (2,) if smoke else (2, 4)
    max_new = 4 if smoke else 8
    max_seq = 64

    def requests_for(cfg, n, rng):
        return [
            Request(
                uid=i,
                prompt=rng.integers(
                    0, cfg.vocab_size, size=int(rng.integers(5, 13))
                ).astype(np.int32),
                max_new_tokens=max_new,
            )
            for i in range(n)
        ]

    records = []
    for arch in archs:
        cfg = get_config(arch).reduced()
        model = build(cfg)
        serve = build_serve(model, mesh, fsdp="data", tp="model")
        params = jax.jit(model.init, out_shardings=serve.param_shardings)(
            jax.random.PRNGKey(0)
        )
        pageable = serve.init_paged_cache is not None
        # SSD archs need multi-token widths to be scan-chunk multiples;
        # width-1 ticks always work, so a chunk-sized prefill_chunk keeps
        # chunked prefill in play for them too
        chunk = cfg.ssm.chunk_size if "ssd" in cfg.attn_pattern else 8
        for conc in concurrency:
            reqs = requests_for(cfg, 2 * conc, np.random.default_rng(0))
            dense_out, dense_stats = _run_server(
                BatchedServer(serve, params, cfg, conc, max_seq,
                              paged=False, prefill_chunk=chunk),
                reqs,
            )
            dense_stats.update(arch=arch, concurrency=conc)
            records.append(dense_stats)
            if not pageable:
                continue
            paged_out, paged_stats = _run_server(
                BatchedServer(serve, params, cfg, conc, max_seq,
                              paged=True, block_size=16, prefill_chunk=chunk),
                reqs,
            )
            paged_stats.update(
                arch=arch, concurrency=conc,
                bitexact_vs_dense=paged_out == dense_out,
            )
            records.append(paged_stats)
            mode = "bitexact" if paged_out == dense_out else "MISMATCH"
            print(f"[serve_bench] {arch} conc={conc}: dense "
                  f"{dense_stats['tok_per_s']:.1f} tok/s, paged "
                  f"{paged_stats['tok_per_s']:.1f} tok/s ({mode}, "
                  f"{paged_stats['high_water_bytes']:.0f}B high-water vs "
                  f"{paged_stats['dense_equiv_bytes']:.0f}B dense)")
        if not pageable:
            print(f"[serve_bench] {arch}: dense-only (recurrent state, "
                  f"nothing to page)")

    record = {
        "mesh": {"data": 2, "model": 2},
        "max_seq": max_seq,
        "max_new_tokens": max_new,
        "smoke": smoke,
        "cells": records,
        "note": NOTE,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[serve_bench] {len(records)} cells -> {out_path}")
    return {"serve": record}

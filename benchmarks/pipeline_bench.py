"""Pipelined-vs-flat SASG step benchmark (BENCH_pipeline.json).

Builds the smoke-sized cnn_cifar SASG step twice — flat workers, and
workers x GPipe stages — on fake CPU devices, times jitted steps, and
records step time plus both exchange traffic views (SASG upload bits and
the stage-axis traffic from core.metrics.PipelineCommModel, split into its
activation-ring and gradient-gather components: the ring is GPipe's
microbatch carries, the gather is the k-sized payload all-gather of the
payload-level stage exchange). Seeds the perf trajectory for the pipeline
composition; run via

  PYTHONPATH=src python -m benchmarks.run --stages 2
"""
from __future__ import annotations

import dataclasses
import json
import time


def run(stages: int = 2, steps: int = 5, out_path: str = "BENCH_pipeline.json") -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.compat
    from repro.configs import get_config
    from repro.core import sasg_config
    from repro.dist.strategy import choose_strategy
    from repro.models import build
    from repro.optim import constant
    from repro.train import build_train_step

    cfg = dataclasses.replace(get_config("cnn_cifar"), d_model=16)
    model = build(cfg)
    scfg = sasg_config(k_ratio=0.05, max_delay=4)

    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32)),
    }

    def bench(mesh, strategy):
        built = build_train_step(model, scfg, mesh, strategy, constant(0.05))
        state = built.init(jax.random.PRNGKey(0))
        state, mets = built.jit_step(state, batch)      # warmup / compile
        jax.block_until_ready(state.params)
        t0 = time.perf_counter()
        for _ in range(steps):
            state, mets = built.jit_step(state, batch)
        jax.block_until_ready(state.params)
        dt = (time.perf_counter() - t0) / steps
        return built, {k: float(v) for k, v in mets.items()}, dt

    mesh_flat = repro.compat.make_mesh((2,), ("data",))
    s_flat = choose_strategy(mesh_flat, sasg_enabled=True)
    bf, mets_f, t_flat = bench(mesh_flat, s_flat)

    mesh_pipe = repro.compat.make_mesh((2, stages), ("data", "stage"))
    s_pipe = choose_strategy(
        mesh_pipe, sasg_enabled=True, pipeline_stages=stages,
        trunk_layers=model.pipeline.n_layers,
    )
    if not s_pipe.pipelined:
        raise ValueError(
            f"stages={stages} does not divide the cnn trunk depth "
            f"{model.pipeline.n_layers}"
        )
    bp, mets_p, t_pipe = bench(mesh_pipe, s_pipe)

    record = {
        "model": "cnn_cifar(d_model=16)",
        "stages": stages,
        "steps_timed": steps,
        "flat": {
            "mesh": {"data": 2},
            "step_time_s": t_flat,
            "bits_wire_per_upload": bf.bits_wire,
            "bits_paper_per_upload": bf.bits_paper,
        },
        "pipelined": {
            "mesh": {"data": 2, "stage": stages},
            "step_time_s": t_pipe,
            "bits_wire_per_upload": bp.bits_wire,
            "bits_paper_per_upload": bp.bits_paper,
            "pipe_bits_per_step": mets_p.get("pipe_bits_step", 0.0),
            "pipe_ring_bits_per_step": mets_p.get("pipe_ring_bits_step", 0.0),
            "pipe_gather_bits_per_step": mets_p.get(
                "pipe_gather_bits_step", 0.0
            ),
        },
        "note": "CPU fake-device timing: compares relative step cost only; "
                "upload bits are identical by construction "
                "(tests/test_pipeline_sasg.py). Stage-axis traffic splits "
                "into the GPipe activation ring (pipe_ring_bits_per_step) "
                "and the k-sized gradient payload gather "
                "(pipe_gather_bits_per_step ~ one compressed upload, NOT "
                "d-sized — the payload-level stage exchange).",
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[pipeline_bench] flat {t_flat*1e3:.1f} ms/step, "
          f"{stages}-stage {t_pipe*1e3:.1f} ms/step -> {out_path}")
    return {"pipeline": record}

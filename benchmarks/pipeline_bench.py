"""Pipelined-vs-flat SASG step benchmark (BENCH_pipeline.json).

Builds the smoke-sized cnn_cifar SASG step three ways — flat workers,
workers x stages under the legacy synchronous GPipe engine (dense f32
activation ring), and workers x stages under the default 1F1B engine with
the compressed ``ActivationLayout`` ring (blocked top-k values + u8 block
indices) — on fake CPU devices, times jitted steps, and records step time
plus both exchange traffic views (SASG upload bits and the stage-axis
traffic from core.metrics.PipelineCommModel, split into its activation-ring
and gradient-gather components). The ``pipelined`` record is the 1F1B
default hot path; ``pipelined_gpipe`` keeps the dense-ring baseline the
regression gate in ``repro.analysis --check`` measures against. Run via

  PYTHONPATH=src python -m benchmarks.run --stages 2
"""
from __future__ import annotations

import dataclasses
import json
import time


def run(stages: int = 2, steps: int = 5, out_path: str = "BENCH_pipeline.json") -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.compat
    from repro.comm.transport import ActivationLayout
    from repro.configs import get_config
    from repro.core import sasg_config
    from repro.dist.strategy import choose_strategy
    from repro.models import build
    from repro.optim import constant
    from repro.train import build_train_step

    cfg = dataclasses.replace(get_config("cnn_cifar"), d_model=16)
    model = build(cfg)
    scfg = sasg_config(k_ratio=0.05, max_delay=4)
    # the benched ring layout: pure blocked top-k at f32 values — the same
    # cell the HLO audit proves byte-exact (cnn_pipe2_sasg_ringcomp); a bf16
    # wire dtype would be silently upcast by XLA's CPU bf16 normalization,
    # so the analytic counters here would overstate the saving
    ring_layout = ActivationLayout(
        wire_dtype="float32", k_ratio=0.05, block_size=256
    )

    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32)),
    }

    def bench(cfg_step, mesh, strategy):
        built = build_train_step(model, cfg_step, mesh, strategy, constant(0.05))
        state = built.init(jax.random.PRNGKey(0))
        state, mets = built.jit_step(state, batch)      # warmup / compile
        jax.block_until_ready(state.params)
        t0 = time.perf_counter()
        for _ in range(steps):
            state, mets = built.jit_step(state, batch)
        jax.block_until_ready(state.params)
        dt = (time.perf_counter() - t0) / steps
        return built, {k: float(v) for k, v in mets.items()}, dt

    mesh_flat = repro.compat.make_mesh((2,), ("data",))
    s_flat = choose_strategy(mesh_flat, sasg_enabled=True)
    bf, mets_f, t_flat = bench(scfg, mesh_flat, s_flat)

    mesh_pipe = repro.compat.make_mesh((2, stages), ("data", "stage"))
    s_pipe = choose_strategy(
        mesh_pipe, sasg_enabled=True, pipeline_stages=stages,
        trunk_layers=model.pipeline.n_layers,
    )
    if not s_pipe.pipelined:
        raise ValueError(
            f"stages={stages} does not divide the cnn trunk depth "
            f"{model.pipeline.n_layers}"
        )
    scfg_gpipe = dataclasses.replace(scfg, pipeline_engine="gpipe")
    scfg_1f1b = dataclasses.replace(
        scfg, pipeline_engine="1f1b", act_layout=ring_layout, overlap=True
    )
    bg, mets_g, t_gpipe = bench(scfg_gpipe, mesh_pipe, s_pipe)
    bp, mets_p, t_pipe = bench(scfg_1f1b, mesh_pipe, s_pipe)

    def pipe_record(built, mets, dt, cfg_step):
        layout = cfg_step.act_layout or ActivationLayout()
        return {
            "mesh": {"data": 2, "stage": stages},
            "engine": cfg_step.pipeline_engine,
            "overlap": cfg_step.overlap,
            "act_layout": {
                "wire_dtype": layout.wire_dtype,
                "k_ratio": layout.k_ratio,
                "block_size": layout.block_size,
            },
            "step_time_s": dt,
            "bits_wire_per_upload": built.bits_wire,
            "bits_paper_per_upload": built.bits_paper,
            "pipe_bits_per_step": mets.get("pipe_bits_step", 0.0),
            "pipe_ring_bits_per_step": mets.get("pipe_ring_bits_step", 0.0),
            "pipe_gather_bits_per_step": mets.get(
                "pipe_gather_bits_step", 0.0
            ),
        }

    record = {
        "model": "cnn_cifar(d_model=16)",
        "stages": stages,
        "steps_timed": steps,
        "flat": {
            "mesh": {"data": 2},
            "step_time_s": t_flat,
            "bits_wire_per_upload": bf.bits_wire,
            "bits_paper_per_upload": bf.bits_paper,
        },
        "pipelined": pipe_record(bp, mets_p, t_pipe, scfg_1f1b),
        "pipelined_gpipe": pipe_record(bg, mets_g, t_gpipe, scfg_gpipe),
        "note": "CPU fake-device timing: compares relative step cost only; "
                "upload bits are identical by construction "
                "(tests/test_pipeline_sasg.py). Stage-axis traffic splits "
                "into the activation ring (pipe_ring_bits_per_step; dense "
                "f32 under gpipe, blocked top-k wire parts under the 1f1b "
                "default — byte-exact vs HLO per the "
                "cnn_pipe2_sasg_ringcomp audit cell) and the k-sized "
                "gradient payload gather (pipe_gather_bits_per_step ~ one "
                "compressed upload, NOT d-sized). The analysis --check gate "
                "fails if pipelined.pipe_ring_bits_per_step regresses above "
                "the compressed ceiling in analysis/baseline.json. Timing "
                "caveat: on a single shared host core wall-clock tracks "
                "TOTAL compute, so 1F1B's bubble win is invisible while its "
                "stage-replicated tail recompute (the price of replicating "
                "loss/grads via the compressed output broadcast instead of "
                "a d-sized stage psum) reads as step-time overhead vs "
                "gpipe; on real parallel devices the schedule, not total "
                "compute, sets the critical path.",
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[pipeline_bench] flat {t_flat*1e3:.1f} ms/step, "
          f"{stages}-stage gpipe {t_gpipe*1e3:.1f} ms/step, "
          f"1f1b+ring-topk {t_pipe*1e3:.1f} ms/step -> {out_path}")
    return {"pipeline": record}

"""Benchmark harness entrypoint: one benchmark per paper table/figure plus
the roofline collector and the pipeline composition bench.

  PYTHONPATH=src python -m benchmarks.run [--full]
  PYTHONPATH=src python -m benchmarks.run --stages 2    # BENCH_pipeline.json
  PYTHONPATH=src python -m benchmarks.run --compressors # BENCH_compressors.json
  PYTHONPATH=src python -m benchmarks.run --serve       # BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.run --elastic     # BENCH_elastic.json
"""
import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run the CNN/CIFAR-scale comparison (slower)")
    ap.add_argument("--stages", type=int, default=0,
                    help="run ONLY the pipelined-vs-flat step bench with this "
                         "many GPipe stages; writes BENCH_pipeline.json")
    ap.add_argument("--compressors", action="store_true",
                    help="run ONLY the compressor x layout sweep (flat and "
                         "2-stage pipelined); writes BENCH_compressors.json")
    ap.add_argument("--serve", action="store_true",
                    help="run ONLY the continuous-batching serve bench "
                         "(dense vs paged KV cache); writes BENCH_serve.json")
    ap.add_argument("--elastic", action="store_true",
                    help="run ONLY the elasticity/chaos recovery bench "
                         "(single-fault matrix + 4->2->4 resize); writes "
                         "BENCH_elastic.json")
    ap.add_argument("--smoke", action="store_true",
                    help="with --serve/--elastic: the reduced CI smoke cells")
    args = ap.parse_args()

    t0 = time.time()
    if args.elastic:
        # fake devices for the elastic worker meshes; must precede jax import
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        )
        from benchmarks import elastic_bench

        elastic_bench.run(smoke=args.smoke)
        print(f"benchmarks.run complete in {time.time()-t0:.1f}s")
        return 0
    if args.serve:
        # fake devices for the 2x2 serve mesh; must precede jax import
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=4"
        )
        from benchmarks import serve_bench

        serve_bench.run(smoke=args.smoke)
        print(f"benchmarks.run complete in {time.time()-t0:.1f}s")
        return 0
    if args.compressors:
        # fake devices for the worker x stage mesh (see --stages note below)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=4"
        )
        from benchmarks import compressor_bench

        compressor_bench.run()
        print(f"benchmarks.run complete in {time.time()-t0:.1f}s")
        return 0
    if args.stages:
        # fake devices for the worker x stage mesh; must precede jax import,
        # and must be APPENDED — XLA flag parsing is last-occurrence-wins, so
        # appending lets this computed count override any pre-existing one
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={max(2 * args.stages, 4)}"
        )
        from benchmarks import pipeline_bench

        pipeline_bench.run(stages=args.stages)
        print(f"benchmarks.run complete in {time.time()-t0:.1f}s")
        return 0

    from benchmarks import (fig_curves, roofline, table1_comm_model,
                            table2_rounds_bits, table3_comm_time)

    results = {}
    results.update(table1_comm_model.run())
    results.update(table2_rounds_bits.run(quick=not args.full))
    results.update(table3_comm_time.run())
    results.update(fig_curves.run())
    results.update(roofline.run())
    print(f"benchmarks.run complete in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness entrypoint: one benchmark per paper table/figure plus
the roofline collector.

  PYTHONPATH=src python -m benchmarks.run [--full]
"""
import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run the CNN/CIFAR-scale comparison (slower)")
    args = ap.parse_args()

    t0 = time.time()
    from benchmarks import (fig_curves, roofline, table1_comm_model,
                            table2_rounds_bits, table3_comm_time)

    results = {}
    results.update(table1_comm_model.run())
    results.update(table2_rounds_bits.run(quick=not args.full))
    results.update(table3_comm_time.run())
    results.update(fig_curves.run())
    results.update(roofline.run())
    print(f"benchmarks.run complete in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Compressor x layout sweep (BENCH_compressors.json).

Builds the smoke-sized cnn_cifar train step for every compressor config —
the per-shard fused-kernel default, its unfused reference, the flat-vector
layouts, and the dense baselines — on a flat 2-worker mesh AND a 2-stage
pipelined mesh, and records per-upload bits (paper + wire views, plus the
transport's per-bucket report) and jitted step wall-time. The
kernel-vs-reference speedup row is the acceptance gate for making
``topk_impl="kernel"`` the per-shard default; run via

  PYTHONPATH=src python -m benchmarks.run --compressors
"""
from __future__ import annotations

import dataclasses
import json
import time


def _sweep_configs():
    from repro.core import CompressorConfig

    return {
        "topk_ef_kernel": CompressorConfig(name="topk_ef", k_ratio=0.05,
                                           topk_impl="kernel", block_size=64),
        "topk_ef_reference": CompressorConfig(name="topk_ef", k_ratio=0.05,
                                              topk_impl="reference",
                                              block_size=64),
        "topk_ef_per_tensor_exact": CompressorConfig(
            name="topk_ef", k_ratio=0.05, layout="per_tensor",
            topk_impl="exact"),
        "topk_ef_flat_global": CompressorConfig(
            name="topk_ef", k_ratio=0.05, bucket="global", topk_impl="exact"),
        "randk": CompressorConfig(name="randk", k_ratio=0.05),
        "qsgd": CompressorConfig(name="qsgd"),
        "signsgd_ef": CompressorConfig(name="signsgd_ef"),
        "terngrad": CompressorConfig(name="terngrad"),
        "identity": CompressorConfig(name="identity"),
    }


def run(stages: int = 2, steps: int = 10,
        out_path: str = "BENCH_compressors.json") -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.compat
    from repro.configs import get_config
    from repro.core import SASGConfig, SelectionConfig
    from repro.dist.strategy import choose_strategy
    from repro.models import build
    from repro.optim import constant
    from repro.train import build_train_step

    cfg = dataclasses.replace(get_config("cnn_cifar"), d_model=16)
    model = build(cfg)

    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32)),
    }

    mesh_flat = repro.compat.make_mesh((2,), ("data",))
    s_flat = choose_strategy(mesh_flat, sasg_enabled=True)
    mesh_pipe = repro.compat.make_mesh((2, stages), ("data", "stage"))
    s_pipe = choose_strategy(
        mesh_pipe, sasg_enabled=True, pipeline_stages=stages,
        trunk_layers=model.pipeline.n_layers,
    )
    assert s_pipe.pipelined

    # Build + warm every cell first, then time in interleaved round-robin
    # rounds and keep the per-cell MIN: CPU wall-time drifts over a long
    # process (throttling, allocator growth), so timing each config in one
    # contiguous block would bias whichever config runs first.
    cells = {}
    for name, comp in _sweep_configs().items():
        scfg = SASGConfig(compressor=comp,
                          selection=SelectionConfig(enabled=False), name=name)
        for mesh_name, mesh, strategy in (
            ("flat", mesh_flat, s_flat), ("pipelined", mesh_pipe, s_pipe)
        ):
            built = build_train_step(model, scfg, mesh, strategy, constant(0.05))
            state = built.init(jax.random.PRNGKey(0))
            state, _ = built.jit_step(state, batch)      # warmup / compile
            jax.block_until_ready(state.params)
            cells[(name, mesh_name)] = [built, state, float("inf")]

    rounds = 3
    for _ in range(rounds):
        for cell in cells.values():
            built, state, best = cell
            t0 = time.perf_counter()
            for _ in range(steps):
                state, _ = built.jit_step(state, batch)
            jax.block_until_ready(state.params)
            cell[1] = state
            cell[2] = min(best, (time.perf_counter() - t0) / steps)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    results = {}
    for name, comp in _sweep_configs().items():
        bf, _, t_flat = cells[(name, "flat")]
        bp, _, t_pipe = cells[(name, "pipelined")]
        assert bf.bits_wire == bp.bits_wire
        report = bf.exchange.transport.bits_report(params_shape)
        results[name] = {
            "layout": bf.exchange.transport.layout,
            "topk_impl": comp.resolved_impl() if comp.name == "topk_ef" else None,
            "bits_paper_per_upload": bf.bits_paper,
            "bits_wire_per_upload": bf.bits_wire,
            "step_time_s_flat": t_flat,
            "step_time_s_pipelined": t_pipe,
            "buckets": report.rows(),
        }
        print(f"[compressor_bench] {name:26s} flat {t_flat*1e3:7.1f} ms  "
              f"{stages}-stage {t_pipe*1e3:7.1f} ms  "
              f"wire {bf.bits_wire:.3e} bits/upload")

    speedup = {
        "flat": results["topk_ef_reference"]["step_time_s_flat"]
        / results["topk_ef_kernel"]["step_time_s_flat"],
        "pipelined": results["topk_ef_reference"]["step_time_s_pipelined"]
        / results["topk_ef_kernel"]["step_time_s_pipelined"],
    }
    record = {
        "model": "cnn_cifar(d_model=16)",
        "stages": stages,
        "steps_timed": steps,
        "compressors": results,
        "kernel_vs_reference_speedup": speedup,
        "note": "CPU fake-device timing (Pallas kernel in interpret mode): "
                "relative step cost only; min over interleaved rounds. "
                "speedup >= 1.0 means the fused kernel hot path is no "
                "slower than the unfused reference.",
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[compressor_bench] kernel-vs-reference speedup "
          f"flat {speedup['flat']:.2f}x, pipelined {speedup['pipelined']:.2f}x "
          f"-> {out_path}")
    return {"compressors": record}

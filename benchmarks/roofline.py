"""Roofline table (deliverable g): collect artifacts/dryrun/*.json into the
per-(arch x shape x mesh) table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os


def load_records(dryrun_dir="artifacts/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        try:
            recs.append(json.load(open(path)))
        except json.JSONDecodeError:
            pass
    return recs


def format_table(recs, mesh="single", log=print):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append((r["arch"], r["shape"], "skipped", r.get("reason", "")))
            continue
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r.get("status", "?"), ""))
            continue
        rf = r["roofline"]
        rows.append((
            r["arch"], r["shape"], "ok",
            f"c={rf['compute_s']:.3f}s m={rf['memory_s']:.3f}s "
            f"x={rf['collective_s']:.3f}s dom={rf['dominant'][:4]} "
            f"frac={rf['roofline_fraction']:.3f} "
            f"useful={rf['useful_flops_ratio']:.2f} "
            f"fit16G={'Y' if r['memory'].get('fits_16g_hbm') else 'N'}"
        ))
    log(f"== Roofline baselines ({mesh}-pod mesh) ==")
    log(f"{'arch':18s} {'shape':12s} {'status':8s} terms")
    for arch, shape, status, detail in rows:
        log(f"{arch:18s} {shape:12s} {status:8s} {detail}")
    log("")
    return rows


def summarize(recs, log=print):
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    bad = [r for r in recs if r.get("status") not in ("ok", "skipped")]
    log(f"cells: {len(ok)} ok, {len(skipped)} skipped (documented), {len(bad)} failed")
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        collb = max(ok, key=lambda r: r["roofline"]["collective_s"]
                    / max(r["roofline"]["step_time_bound_s"], 1e-12))
        log(f"worst roofline fraction: {worst['arch']}/{worst['shape']} "
            f"({worst['roofline']['roofline_fraction']:.4f})")
        log(f"most collective-bound:   {collb['arch']}/{collb['shape']}")
    return {"ok": len(ok), "skipped": len(skipped), "failed": len(bad)}


def run(log=print):
    recs = load_records()
    if not recs:
        log("== Roofline: no dry-run artifacts yet (run repro.launch.run_all_dryruns) ==\n")
        return {"roofline": None}
    for mesh in ("single", "multi"):
        if any(r.get("mesh") == mesh for r in recs):
            format_table(recs, mesh, log)
    stats = summarize(recs, log)
    log("")
    return {"roofline": stats}


if __name__ == "__main__":
    run()

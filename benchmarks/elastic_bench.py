"""Elasticity / chaos benchmark (BENCH_elastic.json).

Runs the single-fault chaos matrix (``FaultPlan.single_fault_matrix``) plus
the 4->2->4 in-run resize plan on the paper's fc_mnist config over a
4-worker fake-CPU mesh, and records per fault class: recovery latency,
steps lost to replay (failed_step - restored_step), restart count, and
whether the final parameters are bit-identical to an uninterrupted run.

Bit-identity expectations are part of the record (``expect_bitexact``):
crash / data_hiccup / save_fail / corrupt_ckpt recoveries replay the exact
batch sequence from an exactly-restored state, so they MUST end
bit-identical; straggler and resize plans change the update history by
design (forced skips / a different worker set) and are instead asserted
deterministic and complete. ``repro.analysis --check`` gates steps-lost
and the bit-identity cells against ``analysis/baseline.json``.

Run via:  PYTHONPATH=src python -m benchmarks.run --elastic [--smoke]
"""
from __future__ import annotations

import json
import os
import tempfile
import time

TOTAL_STEPS = 12
CKPT_EVERY = 4
FAULT_STEP = 7   # strictly between checkpoint steps 4 and 8: real replay
WORKERS = 4

NOTE = (
    "CPU fake-device timing: recovery_latency_s is wall time of the "
    "restore+reseek path only (recompiles excluded by the per-count build "
    "cache). steps_lost counts replayed optimizer steps, bounded by "
    "ckpt_every for any single fault. bitexact_vs_clean compares every "
    "final parameter bit against an uninterrupted run on the same seed."
)


def _max_abs_diff(a, b):
    import jax
    import numpy as np

    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        if np.asarray(x).size else 0.0
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def run(smoke: bool = False, out_path: str = "BENCH_elastic.json") -> dict:
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import PRESETS
    from repro.data import indexed_classification_stream
    from repro.data.synthetic import synthetic_classification
    from repro.models import build
    from repro.optim import constant
    from repro.train import (
        ElasticTrainer,
        FaultPlan,
        TrainerConfig,
        WorkerMembership,
    )

    cfg = get_config("fc_mnist")
    model = build(cfg)
    scfg = PRESETS["sasg"](k_ratio=0.1)
    xs, ys = synthetic_classification(256, cfg.vocab_size, (28, 28, 1), seed=0)
    mem = WorkerMembership(model, scfg, constant(0.05), sasg_enabled=True)
    built = mem.build(WORKERS)

    def data():
        return indexed_classification_stream(xs, ys, batch=8, seed=3)

    def trainer(ckpt_dir, plan=None):
        tc = TrainerConfig(
            total_steps=TOTAL_STEPS, ckpt_dir=ckpt_dir,
            ckpt_every=CKPT_EVERY, log_every=10**9, record_batches=True,
        )
        return ElasticTrainer(
            built, data(), tc, membership=mem, plan=plan,
            log_fn=lambda s: None,
        )

    plans = dict(
        FaultPlan.single_fault_matrix(step=FAULT_STEP, workers=WORKERS)
    )
    plans["resize_4_2_4"] = (
        FaultPlan().worker_drop(CKPT_EVERY, to=WORKERS // 2)
        .worker_join(2 * CKPT_EVERY, to=WORKERS)
    )
    # the faults whose recovery must reproduce the clean run bit-for-bit
    expect_bitexact = {
        "crash", "corrupt_ckpt", "save_fail_transient", "save_fail_lost",
        "data_hiccup",
    }
    if smoke:
        plans = {k: plans[k] for k in ("crash", "worker_drop")}

    cells = []
    with tempfile.TemporaryDirectory() as root:
        t_clean = trainer(os.path.join(root, "clean"))
        clean = t_clean.run(init_key=jax.random.PRNGKey(7))

        for name, plan in plans.items():
            t0 = time.perf_counter()
            t = trainer(os.path.join(root, name), plan=plan)
            state = t.run(init_key=jax.random.PRNGKey(7))
            wall = time.perf_counter() - t0
            recoveries = [e for e in t.events if e["kind"] == "recovery"]
            diff = _max_abs_diff(clean.params, state.params)
            # replay integrity: the last consumption of every step index
            # must match the clean run's batch exactly (zero skip/dup)
            replay_ok = dict(t.batch_log) == dict(t_clean.batch_log)
            cell = {
                "plan": name,
                "faults": [f.kind for f in plan.faults],
                "completed": len(t.history) >= TOTAL_STEPS,
                "restarts": len(recoveries),
                "steps_lost": int(sum(e["steps_lost"] for e in recoveries)),
                "recovery_latency_s": float(
                    sum(e["latency_s"] for e in recoveries)
                ),
                "ckpt_lost": sum(1 for e in t.events if e["kind"] == "ckpt_lost"),
                "resizes": sum(1 for e in t.events if e["kind"] == "resize"),
                "max_param_diff_vs_clean": diff,
                "bitexact_vs_clean": diff == 0.0,
                "expect_bitexact": name in expect_bitexact,
                "replay_exact": bool(replay_ok),
                "wall_s": wall,
            }
            cells.append(cell)
            print(
                f"[elastic_bench] {name}: restarts={cell['restarts']} "
                f"steps_lost={cell['steps_lost']} "
                f"recovery={cell['recovery_latency_s']:.3f}s "
                f"{'bitexact' if cell['bitexact_vs_clean'] else f'diff={diff:.2e}'}"
            )

    record = {
        "arch": "fc_mnist",
        "workers": WORKERS,
        "total_steps": TOTAL_STEPS,
        "ckpt_every": CKPT_EVERY,
        "fault_step": FAULT_STEP,
        "smoke": smoke,
        "cells": cells,
        "note": NOTE,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[elastic_bench] {len(cells)} cells -> {out_path}")
    return {"elastic": record}

"""Single-device M-worker SASG simulator (paper Section 5.1 setting).

The paper's own experiments "simulated ten workers"; this does the same:
a jit'd step that loops over M logical workers (vmapped grads), applies the
selection rule + compressor per worker, and aggregates per eq. (8). It reuses
exactly the core library's compressors/selection — only the transport
(shard_map collectives) is replaced by an in-memory sum — so algorithmic
rounds/bits counts match the distributed path bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import build_transport
from repro.core.sasg import SASGConfig
from repro.core.selection import SelectionState, advance_tau, push_window, should_send
from repro.core.types import tree_sq_norm, tree_sub, tree_where


@dataclass
class SimState:
    params: object
    comp_state: object      # per-worker (stacked M) compressor state
    stale_cache: object     # per-worker last payload (stacked)
    stale_params: object    # per-worker (stacked)
    tau: jax.Array          # (M,)
    window: jax.Array       # (D,)
    step: jax.Array
    rounds: float = 0.0
    bits_paper: float = 0.0


def make_simulator(cfg: SASGConfig, loss_fn: Callable, M: int):
    # the in-memory stand-in for the shard_map exchange still routes layout
    # + compression through the Transport (worker_axes unused: aggregation
    # below is a plain mean), so payloads AND bit accounting match the
    # distributed path for every layout, including the flat/global bucket
    transport = build_transport(cfg.compressor, worker_axes=(), num_workers=M)
    comp = transport.compressor
    sel = cfg.selection

    def init(params):
        def stack(t):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(jnp.asarray(x)[None],
                                           (M,) + jnp.asarray(x).shape).copy(), t
            )

        comp_state = stack(transport.init_state(params))
        payload = transport.zero_payload(params)
        return SimState(
            params=params,
            comp_state=comp_state,
            stale_cache=stack(payload),
            stale_params=stack(params) if sel.enabled else (),
            tau=jnp.ones((M,), jnp.int32),
            window=jnp.zeros((max(sel.max_delay, 1),), jnp.float32),
            step=jnp.zeros((), jnp.int32),
        )

    grad_fn = jax.grad(loss_fn)

    @jax.jit
    def _step(params, comp_state, stale_cache, stale_params, tau, window, step,
              batches, lr, key):
        # per-worker fresh grads (vmap over the worker batch axis)
        g_fresh = jax.vmap(lambda b: grad_fn(params, b))(batches)

        if sel.enabled:
            g_stale = jax.vmap(lambda p, b: grad_fn(p, b))(stale_params, batches)
            a = jnp.broadcast_to(
                sel.alpha_scale / jnp.maximum(lr, 1e-12), (sel.max_delay,)
            ).astype(jnp.float32)

            def decide(gf, gs, t):
                st = SelectionState(tau=t, window=window)
                return should_send(sel, gf, gs, st, a, M)

            send = jax.vmap(decide)(g_fresh, g_stale, tau)
        else:
            send = jnp.ones((M,), bool)
        send = send | (step == 0)

        def per_worker(gf, cstate, cache, snd, k):
            g = jax.tree.map(lambda x: lr * x, gf) if cfg.fold_lr else gf
            payload, cstate_new = transport.encode(cstate, g, k)
            payload = tree_where(snd, payload, cache)
            cstate_new = tree_where(snd, cstate_new, cstate)
            return payload, cstate_new

        keys = jax.random.split(key, M)
        payloads, comp_state_new = jax.vmap(per_worker)(
            g_fresh, comp_state, stale_cache, send, keys
        )

        # aggregate eq. (8): mean of densified payloads
        if comp.kind == "sparse":
            def densify_one(p):
                return jax.tree.map(
                    lambda leaf: leaf.densify(),
                    p, is_leaf=lambda x: hasattr(x, "densify"),
                )

            dense = jax.vmap(densify_one)(payloads)
            mean_c = jax.tree.map(lambda x: x.mean(0), dense)
            update = transport.densify(
                mean_c, jax.tree.map(lambda x: x.astype(jnp.float32), params)
            )
        else:
            update = jax.tree.map(lambda x: x.mean(0), payloads)

        if not cfg.fold_lr:
            update = jax.tree.map(lambda u: lr * u, update)
        new_params = jax.tree.map(lambda p, u: p - u.astype(p.dtype), params, update)

        if sel.enabled:
            stale_params_new = jax.vmap(
                lambda snd, sp: tree_where(snd, params, sp)
            )(send, stale_params)
        else:
            stale_params_new = ()
        tau_new = jnp.where(send, 1, tau + 1)
        delta = tree_sq_norm(tree_sub(new_params, params))
        window_new = push_window(
            SelectionState(tau=tau[0], window=window), delta
        )
        return (new_params, comp_state_new, payloads, stale_params_new, tau_new,
                window_new, step + 1, send)

    bits_paper = transport.bits_paper
    bits_wire = transport.bits_wire

    def step(state: SimState, batches, lr, key) -> SimState:
        (params, cstate, cache, sparams, tau, window, stp, send) = _step(
            state.params, state.comp_state, state.stale_cache, state.stale_params,
            state.tau, state.window, state.step, batches, jnp.float32(lr), key,
        )
        nsent = float(jnp.sum(send))
        return SimState(
            params=params, comp_state=cstate, stale_cache=cache,
            stale_params=sparams, tau=tau, window=window, step=stp,
            rounds=state.rounds + nsent,
            bits_paper=state.bits_paper + nsent * bits_paper(state.params),
        ), nsent

    return init, step, bits_paper, bits_wire

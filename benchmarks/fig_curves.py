"""Figures 2-4: test-accuracy / training-loss vs rounds and bits curves,
emitted as JSON + rendered as ASCII sparklines from the Table-2 runs."""
import json
import os


def _spark(vals, width=40):
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    chars = ".:-=+*#%@"
    idx = [int((v - lo) / rng * (len(chars) - 1)) for v in vals]
    return "".join(chars[i] for i in idx[:width])


def run(out_dir="artifacts/bench", log=print):
    log("== Figs 2-4: accuracy vs rounds/bits ==")
    any_found = False
    for name in ("fc_mnist", "cnn_cifar"):
        path = os.path.join(out_dir, f"curves_{name}.json")
        if not os.path.exists(path):
            continue
        any_found = True
        curves = json.load(open(path))
        log(f"[{name}] accuracy over evaluation points:")
        for algo, pts in curves.items():
            accs = [p["acc"] for p in pts]
            rounds = pts[-1]["rounds"] if pts else 0
            bits = pts[-1]["bits"] if pts else 0
            log(f"  {algo:7s} {_spark(accs)}  final acc={accs[-1]:.3f} "
                f"rounds={rounds:6.0f} bits={bits:.2e}")
    if not any_found:
        log("  (no curves yet — table2 must run first)")
    log("")
    return {"fig_curves": any_found}


if __name__ == "__main__":
    run()

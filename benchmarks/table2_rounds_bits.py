"""Paper Table 2 + Figures 2-4: rounds & bits to reach a target accuracy for
SGD / Sparse / LASG / SASG (M=10 simulated workers, paper Section 5.1
hyperparameters: top-1% sparsity, D=10, alpha_d = 1/(2*lr) for FC).

Offline container -> synthetic-but-matched datasets (Gaussian-mixture images
shaped like MNIST/CIFAR; see repro.data.synthetic). The comparison semantics
(same model, same data, same target accuracy, count rounds/bits) match the
paper; absolute accuracies differ from MNIST's.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.simulator import make_simulator
from repro.configs import get_config
from repro.core import CompressorConfig, SASGConfig, SelectionConfig
from repro.data import synthetic_classification
from repro.models import build

M = 10


def _algo_cfg(name: str, k_ratio=0.01, D=10) -> SASGConfig:
    topk = CompressorConfig(name="topk_ef", k_ratio=k_ratio, topk_impl="sharded",
                            block_size=64)
    dense = CompressorConfig(name="identity")
    sel_on = SelectionConfig(enabled=True, max_delay=D, alpha_scale=0.5)
    sel_off = SelectionConfig(enabled=False)
    return {
        "sgd": SASGConfig(compressor=dense, selection=sel_off, name="sgd"),
        "sparse": SASGConfig(compressor=topk, selection=sel_off, name="sparse"),
        "lasg": SASGConfig(compressor=dense, selection=sel_on, name="lasg"),
        "sasg": SASGConfig(compressor=topk, selection=sel_on, name="sasg"),
    }[name]


def _accuracy(model, params, x, y, bs=512):
    correct = 0
    for i in range(0, len(x), bs):
        logits = model.prefill(params, {"x": jnp.asarray(x[i:i + bs])})
        correct += int((np.asarray(jnp.argmax(logits, -1)) == y[i:i + bs]).sum())
    return correct / len(x)


def run_model(model_name="fc_mnist", steps=400, lr=0.05, target_acc=0.97,
              eval_every=20, seed=0, log=print):
    cfg = get_config(model_name)
    model = build(cfg)
    shape = (28, 28, 1) if cfg.family == "mlp" else (32, 32, 3)
    xall, yall = synthetic_classification(5120, cfg.vocab_size, shape, seed=seed)
    xtr, ytr = xall[:4096], yall[:4096]
    xte, yte = xall[4096:], yall[4096:]
    rng = np.random.default_rng(seed)

    results = {}
    curves = {}
    for algo in ["sgd", "sparse", "lasg", "sasg"]:
        scfg = _algo_cfg(algo)
        init, step, bits_paper, _ = make_simulator(
            scfg, model.loss_fn, M
        )
        params = model.init(jax.random.PRNGKey(seed))
        state = init(params)
        curve = []
        hit = None
        for t in range(steps):
            idx = rng.integers(0, len(xtr), size=(M, 10))  # 10 samples/worker (paper)
            batches = {
                "x": jnp.asarray(xtr[idx]),
                "labels": jnp.asarray(ytr[idx]),
            }
            state, _ = step(state, batches, lr, jax.random.PRNGKey(t))
            if (t + 1) % eval_every == 0 or t == steps - 1:
                acc = _accuracy(model, state.params, xte, yte)
                curve.append(
                    {"step": t + 1, "acc": acc, "rounds": state.rounds,
                     "bits": state.bits_paper}
                )
                if hit is None and acc >= target_acc:
                    hit = curve[-1]
        final = curve[-1]
        row = {
            "algo": algo,
            "rounds_total": final["rounds"],
            "bits_total": final["bits"],
            "final_acc": final["acc"],
            "rounds_to_target": (hit or final)["rounds"],
            "bits_to_target": (hit or final)["bits"],
            "hit_target": hit is not None,
        }
        results[algo] = row
        curves[algo] = curve
        log(f"  {algo:7s} acc={final['acc']:.3f} rounds={final['rounds']:6.0f} "
            f"bits={final['bits']:.3e} (to {target_acc:.0%}: "
            f"rounds={row['rounds_to_target']:.0f} bits={row['bits_to_target']:.3e})")
    return results, curves


def run(quick=True, out_dir="artifacts/bench", log=print):
    os.makedirs(out_dir, exist_ok=True)
    log("== Table 2 / Figs 2-4: rounds & bits to equal accuracy (M=10) ==")
    all_results = {}
    settings = [("fc_mnist", 300 if quick else 800, 0.05, 0.96)]
    if not quick:
        settings.append(("cnn_cifar", 400, 0.02, 0.90))
    for name, steps, lr, tgt in settings:
        log(f"[{name}] target acc {tgt:.0%}")
        res, curves = run_model(name, steps=steps, lr=lr, target_acc=tgt, log=log)
        all_results[name] = res
        with open(os.path.join(out_dir, f"curves_{name}.json"), "w") as f:
            json.dump(curves, f, indent=1)
        # paper's qualitative claims, checked quantitatively:
        if res["sasg"]["hit_target"]:
            assert res["sasg"]["bits_to_target"] <= res["sgd"]["bits_to_target"] / 10, \
                "SASG should cut bits by >=10x vs SGD"
            assert res["sasg"]["rounds_to_target"] <= res["sparse"]["rounds_to_target"] * 1.05, \
                "SASG rounds should not exceed Sparse"
            log("  ok: SASG reduces bits >=10x vs SGD and rounds <= Sparse")
    with open(os.path.join(out_dir, "table2.json"), "w") as f:
        json.dump(all_results, f, indent=1)
    log("")
    return {"table2": all_results}


if __name__ == "__main__":
    run(quick=True)

"""Paper Table 3 / Figs 5-6: communication time + extra overheads.

The paper measures wall-clock on 10 GPUs over 1 Gbps GLOO point-to-point.
Offline here, so the transport is the calibrated analytic LinkModel
(sequential uplink, 1 Gbps, per paper Section 5.1) applied to the *measured*
payload sizes and realized round counts from the Table-2 simulation; the
memory/computation overhead columns are measured directly on the models.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import CompressorConfig
from repro.core.metrics import LinkModel
from repro.core.types import tree_bytes, tree_size
from repro.models import build


def run(out_dir="artifacts/bench", log=print):
    os.makedirs(out_dir, exist_ok=True)
    log("== Table 3: comm time per 100 iterations + adaptive-method overheads ==")
    cfg = get_config("cnn_cifar")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d = tree_size(params)
    M, iters = 10, 100
    link = LinkModel(bandwidth_bps=1e9, latency_s=1e-4, sequential_uplink=True)

    from repro.comm import account

    topk_cfg = CompressorConfig(name="topk_ef", k_ratio=0.01,
                                topk_impl="sharded", block_size=64)
    dense_bits = 32.0 * d
    sparse_bits = account(topk_cfg, params).paper

    # realized skip fraction from the table2 run if available
    skip = 0.35
    t2 = os.path.join(out_dir, "table2.json")
    if os.path.exists(t2):
        res = json.load(open(t2)).get("fc_mnist", {})
        if "sasg" in res and "sgd" in res:
            skip = 1.0 - res["sasg"]["rounds_total"] / max(res["sgd"]["rounds_total"], 1)

    rows = {
        "sgd": link.upload_time(dense_bits, M) * iters,
        "sparse": link.upload_time(sparse_bits, M) * iters,
        "lasg": link.upload_time(dense_bits, M * (1 - skip)) * iters,
        "sasg": link.upload_time(sparse_bits, M * (1 - skip)) * iters,
    }

    # extra computation: the auxiliary gradient (paper: ~1.25 s / 100 iters)
    batch = {"x": jnp.zeros((10, 32, 32, 3)), "labels": jnp.zeros((10,), jnp.int32)}
    g = jax.jit(jax.grad(model.loss_fn))
    jax.block_until_ready(g(params, batch))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = g(params, batch)
    jax.block_until_ready(out)
    aux_time = time.perf_counter() - t0

    # extra memory: stale state held server-side
    mem_lasg = tree_bytes(params) * M            # dense stale grads
    mem_sasg = int(sparse_bits / 8) * M          # sparse stale payloads

    log(f"{'method':8s} {'comm time /100 iter':>20s} {'extra compute':>14s} {'server memory':>14s}")
    for name in ["sgd", "sparse", "lasg", "sasg"]:
        extra_c = f"{aux_time:8.2f}s" if name in ("lasg", "sasg") else "       -"
        extra_m = {"lasg": f"{mem_lasg/2**20:9.2f}MB", "sasg": f"{mem_sasg/2**20:9.2f}MB"}.get(name, "        -")
        log(f"{name:8s} {rows[name]:>19.2f}s {extra_c:>14s} {extra_m:>14s}")

    assert rows["sasg"] < rows["sparse"] < rows["sgd"]
    assert rows["sasg"] < rows["lasg"]
    assert mem_sasg < mem_lasg / 50, "sparse server cache should be ~100x smaller"
    log(f"ok: SASG comm time lowest; server memory {mem_lasg/max(mem_sasg,1):.0f}x smaller than LASG\n")
    out = {"table3": {"comm_time_s": rows, "aux_grad_s": aux_time,
                      "server_mem_lasg": mem_lasg, "server_mem_sasg": mem_sasg,
                      "skip_fraction": skip}}
    with open(os.path.join(out_dir, "table3.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    run()

"""Findings, fingerprints, and the committed baseline.

A :class:`Finding` is one lint hit. Its **fingerprint** is content-derived
(rule id, repo-relative path, enclosing function qualname, the normalized
source of the offending node, and an occurrence counter for identical nodes
in the same scope) — deliberately *not* line-based, so unrelated edits above
a finding do not invalidate the baseline.

The baseline (``analysis/baseline.json``, committed) lists fingerprints of
known, intentionally-accepted findings, each with a one-line justification.
``--check`` fails on any finding whose fingerprint is absent; baseline
entries that no longer fire are reported as stale (warning, not failure, so
a fix elsewhere never breaks the gate).
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")


@dataclass(frozen=True)
class Finding:
    rule: str          # rule id, e.g. "dsize-collective"
    path: str          # repo-relative posix path
    line: int          # 1-based line (display only; not in the fingerprint)
    qualname: str      # enclosing function/class qualname ("<module>" at top)
    snippet: str       # normalized source of the offending node
    message: str
    occurrence: int = 0  # disambiguates identical snippets in one scope

    @property
    def fingerprint(self) -> str:
        key = "|".join(
            [self.rule, self.path, self.qualname, self.snippet,
             str(self.occurrence)]
        )
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def row(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "qualname": self.qualname,
            "snippet": self.snippet,
            "message": self.message,
            "occurrence": self.occurrence,
            "fingerprint": self.fingerprint,
        }

    def __str__(self) -> str:
        return (
            f"{self.path}:{self.line}: [{self.rule}] {self.message}"
            f"  ({self.qualname}: {self.snippet[:80]})"
            f"  [fingerprint {self.fingerprint}]"
        )


@dataclass
class Baseline:
    """Committed known-findings list + audit reference numbers."""

    entries: Dict[str, dict] = field(default_factory=dict)  # fingerprint -> row
    audit: dict = field(default_factory=dict)               # cell -> reference
    # fast-lane bench ceilings, e.g. max_ring_bits_per_step: the committed
    # BENCH_pipeline.json must keep the compressed 1F1B activation ring
    # below this (repro.analysis --check fails otherwise)
    pipeline_bench: dict = field(default_factory=dict)
    # serve-bench gates: every paged cell in the committed BENCH_serve.json
    # must be bit-exact vs its dense twin and keep its pool high-water at or
    # below the dense-equivalent bytes (times max_paged_over_dense_ratio)
    serve_bench: dict = field(default_factory=dict)
    # elastic/chaos-bench gates (BENCH_elastic.json): every recovery cell
    # must complete within max_steps_lost replayed steps, and cells whose
    # fault class promises bit-identity (expect_bitexact) must deliver it
    elastic_bench: dict = field(default_factory=dict)

    def accepts(self, f: Finding) -> bool:
        return f.fingerprint in self.entries

    def stale(self, findings: List[Finding]) -> List[str]:
        live = {f.fingerprint for f in findings}
        return sorted(fp for fp in self.entries if fp not in live)


def load_baseline(path: Optional[str] = None) -> Baseline:
    path = path or BASELINE_PATH
    if not os.path.exists(path):
        return Baseline()
    with open(path) as f:
        raw = json.load(f)
    entries = {e["fingerprint"]: e for e in raw.get("findings", [])}
    return Baseline(
        entries=entries,
        audit=raw.get("audit", {}),
        pipeline_bench=raw.get("pipeline_bench", {}),
        serve_bench=raw.get("serve_bench", {}),
        elastic_bench=raw.get("elastic_bench", {}),
    )


def write_baseline(
    findings: List[Finding],
    justifications: Optional[Dict[str, str]] = None,
    audit: Optional[dict] = None,
    path: Optional[str] = None,
) -> str:
    """Serialize findings (+ optional audit reference) as the new baseline.

    ``justifications`` maps fingerprints to one-line reasons; unknown
    fingerprints get a TODO marker so the diff shows what needs a human
    sentence before committing.
    """
    path = path or BASELINE_PATH
    justifications = justifications or {}
    prev = load_baseline(path) if os.path.exists(path) else Baseline()
    rows = []
    for f in sorted(findings, key=lambda x: (x.path, x.rule, x.qualname,
                                             x.snippet, x.occurrence)):
        just = justifications.get(f.fingerprint)
        if just is None:
            prev_row = prev.entries.get(f.fingerprint, {})
            just = prev_row.get("justification", "TODO: justify or fix")
        rows.append({
            "fingerprint": f.fingerprint,
            "rule": f.rule,
            "path": f.path,
            "qualname": f.qualname,
            "snippet": f.snippet,
            "justification": just,
        })
    payload = {
        "findings": rows,
        "audit": audit if audit is not None else prev.audit,
        "pipeline_bench": prev.pipeline_bench,
        "serve_bench": prev.serve_bench,
        "elastic_bench": prev.elastic_bench,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def split_by_baseline(
    findings: List[Finding], baseline: Baseline
) -> Tuple[List[Finding], List[Finding]]:
    """(new, accepted) partition of ``findings`` against the baseline."""
    new = [f for f in findings if not baseline.accepts(f)]
    accepted = [f for f in findings if baseline.accepts(f)]
    return new, accepted

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

"""CLI for the repro.analysis passes.

CI lint lane (exit non-zero on any non-baselined lint finding, any exchange
wire drift > 1%, any unaccounted d-sized collective, any activation-ring
wire diverging from the PipelineCommModel on a 1F1B cell, or a committed
BENCH_pipeline.json whose ring bits exceed the compressed baseline
ceiling):

  PYTHONPATH=src python -m repro.analysis --check

Other modes:

  --lint-only / --audit-only     run just one pass
  --write-baseline               refresh analysis/baseline.json from the
                                 current sweep (new entries get a TODO
                                 justification to fill in before commit)
  --report PATH                  where to write the audit report
                                 (default: BENCH_comm_audit.json in CWD)
  --lint-report PATH             optionally dump the lint findings as JSON
                                 (sorted + stable: two runs are byte-equal)
"""
import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--check", action="store_true",
                    help="gate mode: non-zero exit on findings/drift")
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--audit-only", action="store_true")
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--report", default="BENCH_comm_audit.json")
    ap.add_argument("--lint-report", default=None)
    ap.add_argument("--root", default=None,
                    help="source root to lint (default: this repro's src)")
    ap.add_argument("--tol", type=float, default=None,
                    help="exchange drift tolerance (default 0.01)")
    args = ap.parse_args(argv)

    from repro.analysis.findings import (
        load_baseline,
        split_by_baseline,
        write_baseline,
    )
    from repro.analysis.lint import report_rows, run_lint

    failed = False
    findings = []
    if not args.audit_only:
        findings = run_lint(root=args.root)
        baseline = load_baseline()
        new, accepted = split_by_baseline(findings, baseline)
        stale = baseline.stale(findings)
        print(f"[lint] {len(findings)} finding(s): {len(new)} new, "
              f"{len(accepted)} baselined, {len(stale)} stale baseline "
              f"entr{'y' if len(stale) == 1 else 'ies'}")
        for f in new:
            print(f"  NEW  {f}")
        for fp in stale:
            ent = baseline.entries[fp]
            print(f"  STALE baseline entry {fp} ({ent.get('rule')} "
                  f"{ent.get('path')}) no longer fires — prune it")
        if args.lint_report:
            payload = json.dumps(
                {"findings": report_rows(findings)},
                indent=1, sort_keys=True,
            ) + "\n"
            with open(args.lint_report, "w", encoding="utf-8") as fh:
                fh.write(payload)
        if new or stale:
            failed = True

    audit_report = None
    if not args.lint_only:
        from repro.analysis import hlo_audit

        tol = args.tol if args.tol is not None else hlo_audit.DEFAULT_TOL
        audit_report = hlo_audit.run_audit(tol=tol)
        problems = hlo_audit.check_report(audit_report)
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump(audit_report, fh, indent=1, sort_keys=True)
            fh.write("\n")
        ncells = len(audit_report["cells"])
        print(f"[audit] {ncells} cell(s) -> {args.report}")
        for name, rec in sorted(audit_report["cells"].items()):
            print(f"  {name}: drift {100 * rec['drift']:.3f}% "
                  f"(HLO {rec['hlo_exchange_wire_bytes']:.0f} B vs "
                  f"counters {rec['expected_exchange_wire_bytes']:.0f} B), "
                  f"{len(rec['dsized_collectives'])} d-sized op(s) "
                  f"{'allowed' if rec['allow_dsized'] else 'forbidden'}")
        for p in problems:
            print(f"  FAIL {p}")
        if problems:
            failed = True

        # fast-lane ring regression gate (BENCH_pipeline.json): the
        # committed bench's compressed 1F1B activation ring must stay below
        # the baseline ceiling — a schedule/layout change that fattens the
        # ring fails --check even before the bench is re-run by hand
        ceiling = load_baseline().pipeline_bench.get("max_ring_bits_per_step")
        if ceiling is not None and os.path.exists("BENCH_pipeline.json"):
            with open("BENCH_pipeline.json", encoding="utf-8") as fh:
                bench = json.load(fh)
            ring = bench.get("pipelined", {}).get("pipe_ring_bits_per_step")
            if ring is None:
                print("  FAIL BENCH_pipeline.json has no "
                      "pipelined.pipe_ring_bits_per_step — regenerate via "
                      "PYTHONPATH=src python -m benchmarks.run --stages 2")
                failed = True
            elif ring > ceiling:
                print(f"  FAIL pipeline bench ring {ring:.0f} bits/step "
                      f"exceeds the compressed baseline ceiling "
                      f"{ceiling:.0f} (analysis/baseline.json "
                      f"pipeline_bench.max_ring_bits_per_step)")
                failed = True
            else:
                print(f"[bench] pipeline ring {ring:.0f} bits/step <= "
                      f"ceiling {ceiling:.0f}")

        # serve-bench regression gate (BENCH_serve.json): every paged cell
        # must be bit-exact vs its dense twin on the identity cache dtype,
        # and its block-pool byte high-water must stay at or below the
        # dense-equivalent cache — the engine's two acceptance claims
        sb = load_baseline().serve_bench
        if sb and os.path.exists("BENCH_serve.json"):
            with open("BENCH_serve.json", encoding="utf-8") as fh:
                sbench = json.load(fh)
            ratio = float(sb.get("max_paged_over_dense_bytes_ratio", 1.0))
            paged_cells = [c for c in sbench.get("cells", [])
                           if c.get("paged")]
            if sb.get("require_paged_cells") and not paged_cells:
                print("  FAIL BENCH_serve.json has no paged cells — "
                      "regenerate via PYTHONPATH=src python -m "
                      "benchmarks.run --serve")
                failed = True
            n_bad = 0
            for c in paged_cells:
                cell = f"{c.get('arch')}@conc{c.get('concurrency')}"
                if sb.get("require_bitexact") and not c.get("bitexact_vs_dense"):
                    print(f"  FAIL serve bench {cell}: paged tokens diverge "
                          f"from the dense engine on the identity cache "
                          f"dtype ({c.get('cache_dtype')})")
                    failed, n_bad = True, n_bad + 1
                hw, de = c.get("high_water_bytes"), c.get("dense_equiv_bytes")
                if hw is not None and de and hw > de * ratio:
                    print(f"  FAIL serve bench {cell}: paged high-water "
                          f"{hw:.0f} B exceeds {ratio:.2f}x the "
                          f"dense-equivalent {de:.0f} B")
                    failed, n_bad = True, n_bad + 1
            if paged_cells and not n_bad:
                print(f"[bench] serve: {len(paged_cells)} paged cell(s) "
                      f"bit-exact, high-water <= {ratio:.2f}x dense")

        # elastic/chaos recovery gate (BENCH_elastic.json): every cell must
        # complete within its restart budget, replay must stay within the
        # steps-lost ceiling (bounded by ckpt_every for single faults), and
        # fault classes that promise bit-identity vs an uninterrupted run
        # (crash / data / save / corrupt-ckpt recoveries) must deliver it
        eb = load_baseline().elastic_bench
        if eb and os.path.exists("BENCH_elastic.json"):
            with open("BENCH_elastic.json", encoding="utf-8") as fh:
                ebench = json.load(fh)
            ecells = ebench.get("cells", [])
            if eb.get("require_cells") and not ecells:
                print("  FAIL BENCH_elastic.json has no cells — regenerate "
                      "via PYTHONPATH=src python -m benchmarks.run --elastic")
                failed = True
            max_lost = eb.get("max_steps_lost")
            n_bad = 0
            for c in ecells:
                cell = c.get("plan", "?")
                if not c.get("completed"):
                    print(f"  FAIL elastic bench {cell}: run did not reach "
                          f"total_steps (restarts={c.get('restarts')})")
                    failed, n_bad = True, n_bad + 1
                if max_lost is not None and c.get("steps_lost", 0) > max_lost:
                    print(f"  FAIL elastic bench {cell}: {c.get('steps_lost')} "
                          f"steps lost to replay exceeds the ceiling "
                          f"{max_lost} (analysis/baseline.json "
                          f"elastic_bench.max_steps_lost)")
                    failed, n_bad = True, n_bad + 1
                if (eb.get("require_bitexact")
                        and c.get("expect_bitexact")
                        and not c.get("bitexact_vs_clean")):
                    print(f"  FAIL elastic bench {cell}: recovery promised "
                          f"bit-identity but final params diverge by "
                          f"{c.get('max_param_diff_vs_clean'):.2e}")
                    failed, n_bad = True, n_bad + 1
                if eb.get("require_replay_exact") and not c.get("replay_exact"):
                    print(f"  FAIL elastic bench {cell}: batch replay skipped "
                          f"or duplicated data (replay_exact=false)")
                    failed, n_bad = True, n_bad + 1
            if ecells and not n_bad:
                print(f"[bench] elastic: {len(ecells)} chaos cell(s) "
                      f"recovered, steps_lost <= {max_lost}, promised "
                      f"bit-identity held")

    if args.write_baseline:
        audit_summary = None
        if audit_report is not None:
            audit_summary = {
                name: {
                    "drift": rec["drift"],
                    "dsized_collectives": rec["dsized_collectives"],
                }
                for name, rec in sorted(audit_report["cells"].items())
            }
        path = write_baseline(findings, audit=audit_summary)
        print(f"[baseline] wrote {len(findings)} entr"
              f"{'y' if len(findings) == 1 else 'ies'} -> {path}")
        return 0

    if args.check and failed:
        print("analysis: FAILED (see findings above)")
        return 1
    print("analysis: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

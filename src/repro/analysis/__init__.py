"""Static analysis for distributed correctness (`python -m repro.analysis`).

Two cooperating passes keep the paper's headline quantity — communication
bits — honest:

- **Pass 1, AST lint** (:mod:`repro.analysis.lint` + ``rules/``): source-level
  rules over ``src/repro`` for distributed-JAX correctness: hardcoded
  collective axis names, tracer-unsafe host patterns inside traced step
  code, d-sized (full-gradient-shaped) collectives outside the
  ``repro.comm`` Transport seam, and compressor/bits registry consistency.
- **Pass 2, HLO collective audit** (:mod:`repro.analysis.hlo_audit`):
  compile a small config x strategy matrix, attribute every collective in
  the optimized HLO to a mesh axis, and cross-check the wire bytes that
  actually cross links against the analytic ``repro.comm.bits`` counters.

Known, intentionally-accepted findings live in ``baseline.json`` next to
this package; ``--check`` gates CI on anything not in the baseline.
"""
from .findings import Finding, load_baseline  # noqa: F401
from .lint import run_lint  # noqa: F401

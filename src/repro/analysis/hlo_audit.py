"""Pass 2: HLO collective audit — compiled wire bytes vs the bit counters.

The analytic counters in ``repro.comm.bits`` are what every benchmark and
figure reports; this pass checks them against what XLA actually emits. For a
small config x strategy x layout matrix it compiles the real train step,
parses every collective out of the optimized (SPMD-partitioned, per-device)
HLO, attributes each one to the mesh axes its replica groups span, and then:

- cross-checks the exchange-path wire bytes against ``bits_wire``. For the
  sparse layouts the exchange is the worker-axis all-gather of the fixed-k
  payload: ring wire per device = (M-1)/M x result = (M-1) x payload, so the
  expected bytes are ``(M-1) * bits_wire / 8``. For dense psum it is the
  worker-axis all-reduce: ``2*(M-1)/M * bits_wire / 8``. Drift beyond the
  tolerance (default 1%; measured drift on the seed matrix is exactly 0 —
  the flat cnn cell's gather wires 87664 bytes against bits_wire=701312)
  fails the audit.
- itemizes every *d-sized* collective that is NOT the accounted exchange:
  anything whose per-device result is at least ``min(0.5 x largest param
  leaf, one compressed upload)`` bytes. Any such collective fails the audit
  (the whole point of the paper is that nothing d-sized crosses the wire).
  On pipelined cells the activation ring — the per-tick collective-permute
  carries plus the all-reduce that replicates the finished microbatch
  outputs — is activation traffic, not gradient traffic: permutes are
  classified by op type (the stage axis moves nothing else point-to-point),
  and the broadcast all-reduces by their per-device result matching the
  ``ActivationLayout``-ENCODED output block's wire parts
  (``ring_result_bytes`` — the dense wire-dtype cast, or the (values,
  indices) part sizes of the blocked top-k; no dense-shape exemption). Ring
  traffic is itemized under ``ring_collectives`` and RECLASSIFIED rather
  than gated away: its total wire bytes must match the analytic
  ``PipelineCommModel`` (``ring_drift`` <= ``RING_TOL``, scaled by the
  number of pipeline passes the selection rule takes per step), so an
  engine change that silently fattens the ring fails the audit even though
  nothing is "d-sized gradient" traffic. Everything else on the stage axis
  is GRADIENT traffic (``stage_grad_wire_bytes``) and, since the
  payload-level stage gather landed, must be k-sized: a reintroduced
  d-sized trunk gather/psum fails the gate like any other cell.

Replica-group attribution: HLO spells groups either as an explicit list
(``{{0,2},{1,3}}``) or iota form (``[2,2]<=[2,2]T(1,0)``), and
collective-permute uses ``source_target_pairs``. Mapping device ids back to
mesh coordinates, the axes along which group members vary name the
collective's mesh axes — that is the classification backbone.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch.hlo_analysis import (
    _COLL_RE,
    _OPNAME_RE,
    _shape_bytes,
    parse_replica_groups,
    parse_source_target_pairs,
    wire_factor,
)

DEFAULT_TOL = 0.01
# activation-ring wire vs PipelineCommModel: measured drift on the seed
# matrix is exactly 0 (the 1F1B model is byte-exact per device), so the
# tolerance only absorbs wire_factor rounding
RING_TOL = 0.01


# ---------------------------------------------------------------------------
# collective extraction + mesh-axis attribution
# ---------------------------------------------------------------------------

@dataclass
class CollectiveOp:
    kind: str               # all-reduce | all-gather | ... | collective-permute
    result_bytes: int       # per-device result-shape bytes
    wire_bytes: float       # ring-model bytes crossing links, per device
    group_size: int
    axes: Tuple[str, ...]   # mesh axes the replica groups span
    shapes: str             # result type string (truncated)
    op_name: str


def device_coords(mesh) -> Dict[int, Tuple[int, ...]]:
    """device id -> coordinate tuple in the mesh's logical array."""
    import numpy as np

    coords: Dict[int, Tuple[int, ...]] = {}
    arr = np.asarray(mesh.devices)
    for idx in np.ndindex(arr.shape):
        coords[arr[idx].id] = tuple(int(i) for i in idx)
    return coords


def classify_axes(
    mesh,
    groups: Optional[List[List[int]]],
    pairs: Optional[List[Tuple[int, int]]] = None,
) -> Tuple[str, ...]:
    """The mesh axes along which a collective's participants vary.

    ``groups=None, pairs=None`` (no replica_groups attribute) means the
    default single group over every device."""
    coords = device_coords(mesh)
    names = tuple(mesh.axis_names)
    if pairs is not None:
        groups = [[s, t] for s, t in pairs]
    if not groups:
        groups = [sorted(coords)]
    varying = set()
    for grp in groups:
        cs = [coords[d] for d in grp if d in coords]
        for ax in range(len(names)):
            if len({c[ax] for c in cs}) > 1:
                varying.add(ax)
    return tuple(names[ax] for ax in sorted(varying))


def parse_collective_ops(hlo_text: str, mesh) -> List[CollectiveOp]:
    """Every collective in the HLO, with mesh-axis attribution."""
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        rb = _shape_bytes(shapes)
        pairs = (
            parse_source_target_pairs(line)
            if kind == "collective-permute" else None
        )
        groups = parse_replica_groups(line) if pairs is None else None
        axes = classify_axes(mesh, groups, pairs)
        g = len(groups[0]) if groups else (2 if pairs else 1)
        nm = _OPNAME_RE.search(line)
        ops.append(CollectiveOp(
            kind=kind,
            result_bytes=rb,
            wire_bytes=wire_factor(kind, g) * rb,
            group_size=g,
            axes=axes,
            shapes=shapes[:80],
            op_name=nm.group(1) if nm else "",
        ))
    return ops


# ---------------------------------------------------------------------------
# the audit matrix
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AuditCell:
    """One compile-and-audit point of the config x strategy x layout matrix."""

    name: str
    algo: str = "sasg"                    # preset in repro.core.sasg.PRESETS
    arch: str = "cnn_cifar"
    d_model: int = 16
    k_ratio: float = 0.05
    max_delay: int = 4
    batch: int = 8
    mesh_shape: Tuple[int, ...] = (2,)
    mesh_axes: Tuple[str, ...] = ("data",)
    pipeline_stages: int = 1
    layout: Optional[str] = None          # compressor layout override
    # activation-ring wire layout override: (wire_dtype, k_ratio, block_size)
    # applied as SASGConfig.act_layout = ActivationLayout(*act_layout)
    act_layout: Optional[Tuple[str, float, int]] = None
    allow_dsized: bool = False            # escape hatch; no default cell uses it


DEFAULT_CELLS: Tuple[AuditCell, ...] = (
    AuditCell(name="cnn_flat_sasg"),
    AuditCell(name="cnn_flat_sasg_pertensor", layout="per_tensor"),
    # strict since the payload-level stage gather: only the activation ring
    # (classified via ring_result_bytes) is d-sized on this cell
    AuditCell(
        name="cnn_pipe2_sasg",
        mesh_shape=(2, 2), mesh_axes=("data", "stage"),
        pipeline_stages=2,
    ),
    # compressed activation ring: the broadcast all-reduces now carry the
    # encoded (values, u8 indices) parts — NOT the dense block shape — so
    # this cell proves reclassification follows the layout, and the ring
    # gate proves the compressed model still matches the compiled bytes.
    # Values stay f32: XLA's CPU bf16 normalization hoists the decode-side
    # f32 convert ACROSS the ring collectives, so a bf16 wire dtype would
    # compile to f32 on this backend and the byte-exact gate would
    # (correctly) flag the 2x — cast-on-the-wire is audited only where the
    # backend keeps bf16 collectives native.
    AuditCell(
        name="cnn_pipe2_sasg_ringcomp",
        mesh_shape=(2, 2), mesh_axes=("data", "stage"),
        pipeline_stages=2,
        act_layout=("float32", 0.05, 256),
    ),
    AuditCell(name="cnn_flat_lasg_dense", algo="lasg"),
)


def _build_cell(cell: AuditCell):
    from repro import compat
    from repro.configs import get_config
    from repro.core.sasg import PRESETS
    from repro.dist.strategy import choose_strategy
    from repro.models import build
    from repro.optim import constant
    from repro.train import build_train_step

    if cell.arch != "cnn_cifar":
        raise NotImplementedError(
            f"audit batch builder only knows cnn_cifar, got {cell.arch!r}"
        )
    model = build(dataclasses.replace(get_config(cell.arch), d_model=cell.d_model))
    mesh = compat.make_mesh(cell.mesh_shape, cell.mesh_axes)
    preset = PRESETS[cell.algo]
    kw = {"max_delay": cell.max_delay}
    if cell.algo in ("sasg", "sparse"):
        kw["k_ratio"] = cell.k_ratio
    if cell.algo == "sgd":
        kw = {}
    scfg = preset(**kw)
    if cell.layout is not None:
        scfg = dataclasses.replace(
            scfg,
            compressor=dataclasses.replace(scfg.compressor, layout=cell.layout),
        )
    if cell.act_layout is not None:
        from repro.comm.transport import ActivationLayout

        scfg = dataclasses.replace(
            scfg, act_layout=ActivationLayout(*cell.act_layout)
        )
    strategy = choose_strategy(
        mesh, sasg_enabled=True,
        pipeline_stages=cell.pipeline_stages,
        trunk_layers=model.pipeline.n_layers if model.pipeline else 0,
    )
    built = build_train_step(model, scfg, mesh, strategy, constant(0.05))
    return model, mesh, strategy, built


def _compile_hlo(cell: AuditCell, mesh, built) -> str:
    import jax
    import jax.numpy as jnp

    batch_shape = {
        "x": jax.ShapeDtypeStruct((cell.batch, 32, 32, 3), jnp.float32),
        "labels": jax.ShapeDtypeStruct((cell.batch,), jnp.int32),
    }
    bshard = built.batch_sharding_fn(batch_shape)
    batch_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        batch_shape, bshard,
    )
    state = built.init(jax.random.PRNGKey(0))
    return jax.jit(built.step).lower(state, batch_sds).compile().as_text()


def _expected_exchange(kind: str, M: int, bits_wire: float) -> Tuple[str, float]:
    """(HLO op kind, expected per-device wire bytes) for the exchange."""
    if kind == "sparse":
        # all-gather of M payloads: ring wire = (M-1)/M x result = (M-1) x payload
        return "all-gather", (M - 1) * bits_wire / 8.0
    # dense psum: ring all-reduce = 2*(M-1)/M x payload
    return "all-reduce", 2.0 * (M - 1) / M * bits_wire / 8.0


def audit_built(
    cell: AuditCell, mesh, strategy, built, hlo: str,
    tol: float = DEFAULT_TOL,
    ring_result_bytes: Tuple[int, ...] = (),
) -> dict:
    """Core audit of one compiled cell (split out so tests can inject).

    ``ring_result_bytes`` names the per-device result sizes of the
    activation ring's all-reduces: the wire parts of the
    ``ActivationLayout``-encoded finished-output block (identity layout ->
    the dense ``prepare`` block, = the old GPipe psum shape; compressed
    layouts -> the values part + the index part; computed by ``audit_cell``
    from an eval_shape of ``layout.encode``). Together with every
    stage-axis collective-permute these are classified as activation-ring
    traffic — itemized and cross-checked against the analytic ring model by
    ``audit_cell``, not gated as d-sized gradient traffic (module
    docstring)."""
    import numpy as np

    ops = parse_collective_ops(hlo, mesh)
    M = strategy.num_workers
    worker = tuple(sorted(strategy.worker_axes))
    kind = built.exchange.transport.kind
    exch_op, expected_bytes = _expected_exchange(kind, M, built.bits_wire)

    def is_exchange(op: CollectiveOp) -> bool:
        return op.kind == exch_op and tuple(sorted(op.axes)) == worker

    hlo_exchange_bytes = sum(op.wire_bytes for op in ops if is_exchange(op))
    drift = (
        abs(hlo_exchange_bytes - expected_bytes) / expected_bytes
        if expected_bytes else 0.0
    )

    # d-sized threshold: half the largest param leaf, but never above one
    # compressed upload — a collective that moves more than the upload it
    # was supposed to replace is d-scale by the paper's own yardstick.
    import jax

    state_shape = jax.eval_shape(built.init, jax.random.PRNGKey(0))
    largest_leaf = max(
        int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(state_shape.params)
    )
    threshold = min(0.5 * largest_leaf, built.bits_wire / 8.0)

    stage_ax = strategy.stage_axis if strategy.pipelined else None

    def is_ring(op: CollectiveOp) -> bool:
        # activation ring: the per-tick microbatch carries (ppermute) and
        # the output-replicating psum, whose per-device result is one of
        # the ENCODED output block's wire parts — NOT gradient traffic
        return (
            stage_ax is not None
            and stage_ax in op.axes
            and (
                op.kind == "collective-permute"
                or (op.kind == "all-reduce"
                    and op.result_bytes in ring_result_bytes)
            )
        )

    dsized = [
        op for op in ops
        if op.result_bytes >= threshold
        and not is_exchange(op) and not is_ring(op)
    ]
    dsized_rows = _count_rows(dsized)

    record = {
        "algo": cell.algo,
        "layout": built.exchange.transport.compressor.layout,
        "exchange_kind": kind,
        "mesh": {a: int(s) for a, s in zip(cell.mesh_axes, cell.mesh_shape)},
        "num_workers": M,
        "pipeline_stages": strategy.pipeline_stages,
        "bits_paper": built.bits_paper,
        "bits_wire": built.bits_wire,
        "expected_exchange_wire_bytes": expected_bytes,
        "hlo_exchange_wire_bytes": hlo_exchange_bytes,
        "drift": drift,
        "drift_ok": drift <= tol,
        "dsized_threshold_bytes": int(threshold),
        "dsized_collectives": dsized_rows,
        "dsized_ok": cell.allow_dsized or not dsized_rows,
        "allow_dsized": cell.allow_dsized,
        "total_collectives": len(ops),
        "total_wire_bytes": round(sum(op.wire_bytes for op in ops), 1),
    }

    if strategy.pipelined:
        stage_wire = sum(op.wire_bytes for op in ops if stage_ax in op.axes)
        ring_ops = [op for op in ops if is_ring(op)]
        ring_wire = sum(op.wire_bytes for op in ring_ops)
        record["stage_axis_wire_bytes"] = round(stage_wire, 1)
        record["ring_collectives"] = _count_rows(ring_ops)
        record["ring_wire_bytes"] = round(ring_wire, 1)
        # stage-axis GRADIENT traffic = everything on the stage axis that is
        # not the activation ring; since the payload-level gather this must
        # be k-scale (the stage payload all-gather + tiny prepare psums)
        record["stage_grad_wire_bytes"] = round(stage_wire - ring_wire, 1)
    return record


def _freeze_row(op: CollectiveOp) -> tuple:
    return (
        ("kind", op.kind),
        ("shapes", op.shapes),
        ("axes", tuple(op.axes)),
        ("result_bytes", int(op.result_bytes)),
        ("wire_bytes", round(op.wire_bytes, 1)),
    )


def _count_rows(ops: Sequence[CollectiveOp]) -> List[dict]:
    """Dedupe identical instructions (HLO repeats per-leaf ops) into counted
    rows, largest result first."""
    counted: Dict[tuple, int] = {}
    for op in ops:
        key = _freeze_row(op)
        counted[key] = counted.get(key, 0) + 1
    rows = sorted(
        (dict(k, count=n) for k, n in counted.items()),
        key=lambda r: (-r["result_bytes"], r["kind"], r["shapes"]),
    )
    for r in rows:
        r["axes"] = list(r["axes"])
    return rows


def audit_cell(cell: AuditCell, tol: float = DEFAULT_TOL) -> dict:
    """Build, compile and audit one cell of the matrix."""
    model, mesh, strategy, built = _build_cell(cell)
    hlo = _compile_hlo(cell, mesh, built)
    rrb = (
        _ring_result_bytes(cell, model, strategy, built)
        if strategy.pipelined else ()
    )
    record = audit_built(
        cell, mesh, strategy, built, hlo, tol=tol, ring_result_bytes=rrb
    )

    if strategy.pipelined:
        # the analytic model the step publishes as pipe_*_bits_step
        pipe = _pipe_model(cell, model, strategy, built)
        record["pipe_model_bytes_per_step"] = int(pipe.bits_per_step() // 8)
        if built.exchange.config.pipeline_engine == "1f1b":
            # ring reclassification gate: the itemized ring wire bytes must
            # MATCH the analytic model, not just be exempted. The compiled
            # step walks the pipeline once per gradient pass — twice when
            # the selection rule also probes the stale gradient (audit
            # cells use probe_fraction=1, a full second pass) — and the
            # model counts bits summed over stages while the HLO is
            # per-device, hence the passes/stages scaling.
            passes = 2 if built.exchange.config.selection.enabled else 1
            expect = (
                passes * pipe.ring_bits_per_step()
                / 8.0 / strategy.pipeline_stages
            )
            ring = record.get("ring_wire_bytes", 0.0)
            drift = abs(ring - expect) / expect if expect else 0.0
            record["ring_passes"] = passes
            record["ring_model_wire_bytes"] = round(expect, 1)
            record["ring_drift"] = drift
            record["ring_ok"] = drift <= RING_TOL
    return record


def _prepare_activation(cell: AuditCell, model, strategy):
    """eval_shape of ``pipeline.prepare`` on one worker's batch slice."""
    import jax
    import jax.numpy as jnp

    M = strategy.num_workers
    wbatch = {
        "x": jax.ShapeDtypeStruct((cell.batch // M, 32, 32, 3), jnp.float32),
        "labels": jax.ShapeDtypeStruct((cell.batch // M,), jnp.int32),
    }
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return jax.eval_shape(model.pipeline.prepare, pshape, wbatch)


def _ring_result_bytes(
    cell: AuditCell, model, strategy, built
) -> Tuple[int, ...]:
    """Per-device result bytes of the ring's output-replicating all-reduces:
    the wire parts of the layout-ENCODED finished-output block (all
    microbatches stacked). Identity layout -> one dense f32 part, byte-equal
    to the old GPipe psum shape; compressed layouts -> the wire-dtype values
    part + the block-local index part."""
    import jax
    import numpy as np

    from repro.comm.transport import ActivationLayout

    h = _prepare_activation(cell, model, strategy)
    layout = built.exchange.config.act_layout or ActivationLayout()
    parts = jax.eval_shape(
        layout.encode, jax.ShapeDtypeStruct(h.shape, h.dtype)
    )
    return tuple(
        int(np.prod(p.shape)) * np.dtype(p.dtype).itemsize for p in parts
    )


def _pipe_model(cell: AuditCell, model, strategy, built):
    """The engine-aware ``PipelineCommModel`` for a built pipelined cell —
    the same model the train step publishes as ``pipe_*_bits_step``."""
    import jax
    import numpy as np

    from repro.comm.transport import ActivationLayout
    from repro.core import metrics as CM
    from repro.dist.pipeline import resolve_microbatches
    from repro.train.step import pipeline_gather_bits

    h = _prepare_activation(cell, model, strategy)
    nm = resolve_microbatches(
        h.shape[0], strategy.microbatches or strategy.pipeline_stages
    )
    act_elems = int(np.prod(h.shape)) // nm
    layout = built.exchange.config.act_layout or ActivationLayout()
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return CM.PipelineCommModel(
        stages=strategy.pipeline_stages, n_micro=nm,
        act_elems=act_elems,
        bits_per_elem=h.dtype.itemsize * 8,
        gather_bits=pipeline_gather_bits(
            built.exchange.transport, pshape, model.pipeline, strategy,
            built.exchange.config.selection,
        ),
        engine=built.exchange.config.pipeline_engine,
        hop_payload_bits=layout.payload_bits(act_elems),
        bcast_payload_bits=layout.payload_bits(nm * act_elems),
    )


def run_audit(
    cells: Sequence[AuditCell] = DEFAULT_CELLS, tol: float = DEFAULT_TOL,
) -> dict:
    """Audit the whole matrix -> the BENCH_comm_audit.json payload."""
    report = {
        "tolerance": tol,
        "note": (
            "per-device wire bytes from optimized HLO (ring collective "
            "model) vs the analytic repro.comm.bits counters; "
            "d-sized = result >= min(largest param leaf / 2, one upload)"
        ),
        "cells": {},
    }
    for cell in cells:
        report["cells"][cell.name] = audit_cell(cell, tol=tol)
    return report


def check_report(report: dict) -> List[str]:
    """Gate: problems that must fail CI. Empty list = audit clean."""
    problems: List[str] = []
    for name, rec in sorted(report.get("cells", {}).items()):
        if not rec.get("drift_ok", True):
            problems.append(
                f"{name}: exchange wire drift {100 * rec['drift']:.2f}% "
                f"(HLO {rec['hlo_exchange_wire_bytes']:.0f} B vs counters "
                f"{rec['expected_exchange_wire_bytes']:.0f} B) exceeds "
                f"{100 * report.get('tolerance', DEFAULT_TOL):.1f}%"
            )
        if not rec.get("dsized_ok", True):
            items = ", ".join(
                f"{r['kind']} {r['shapes']} over {'/'.join(r['axes'])}"
                for r in rec.get("dsized_collectives", [])[:4]
            )
            problems.append(
                f"{name}: d-sized collective(s) outside the accounted "
                f"exchange on a cell that forbids them: {items}"
            )
        if not rec.get("ring_ok", True):
            problems.append(
                f"{name}: activation-ring wire {rec['ring_wire_bytes']:.0f} B "
                f"diverges {100 * rec['ring_drift']:.2f}% from the "
                f"PipelineCommModel "
                f"({rec['ring_model_wire_bytes']:.0f} B over "
                f"{rec['ring_passes']} pipeline pass(es)) — the ring is "
                f"reclassified, not exempt; its bytes must stay accounted"
            )
    return problems

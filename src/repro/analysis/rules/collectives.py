"""Rule ``dsize-collective``: data-moving collectives belong to the comm seam.

The paper's bit savings live or die on what crosses the wire, and
``BENCH_pipeline.json`` showed d-sized collectives slipping onto the hot
path unnoticed (ring traffic ~15x the compressed upload). The structural
fix: every collective that moves *data* (``psum``/``pmean``/``all_gather``/
``ppermute``/``psum_scatter``/``all_to_all`` on arrays) must live inside
``repro/comm/`` — the ``Transport`` seam that owns layout, collectives, and
the bit counters — so nothing can cross the wire unaccounted.

Exempt:
- metadata queries (``axis_index``/``axis_size``) — no payload;
- collectives whose operand is a numeric literal (``psum(1, axis)`` is the
  idiomatic static axis-size query);
- ``repro/comm/`` itself and ``repro/compat.py`` (shim for the above).

Known-accepted sites (the GPipe activation ring in ``dist/pipeline.py`` —
activation traffic by construction, classified and itemized by the HLO
audit's ``ring_collectives``) are recorded in ``analysis/baseline.json``
with justifications. The stage GRADIENT exchange no longer appears here:
it goes through the ``repro.comm`` Transport (the k-sized payload gather on
the hot path, ``stage_combine_leaf`` on the dense fallback).
"""
from __future__ import annotations

from typing import List

from repro.analysis.findings import Finding

from ._common import (
    AXIS_QUERIES,
    ScopedVisitor,
    collective_name,
    is_numeric_literal,
)

EXEMPT_PATHS = ("repro/comm/", "repro/compat.py", "repro/analysis/")


class _Visitor(ScopedVisitor):
    def __init__(self, ctx):
        super().__init__()
        self.ctx = ctx
        self.findings: List[Finding] = []

    def visit_Call(self, node):  # noqa: N802
        name = collective_name(node)
        if (name is not None and name not in AXIS_QUERIES
                and node.args and not is_numeric_literal(node.args[0])):
            self.findings.append(self.ctx.finding(
                "dsize-collective", node, self.qualname,
                f"data-moving collective lax.{name} outside the repro.comm "
                "Transport seam; route it through Transport (or record it "
                "in analysis/baseline.json with a justification) so the "
                "bit counters see it",
            ))
        self.generic_visit(node)


def check_dsize_collectives(ctx) -> List[Finding]:
    if any(ctx.path.startswith(p) for p in EXEMPT_PATHS):
        return []
    v = _Visitor(ctx)
    v.visit(ctx.tree)
    return v.findings

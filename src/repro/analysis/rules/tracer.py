"""Rule ``tracer-leak``: host-Python patterns that break under jit tracing.

Scoped to the modules whose functions run inside ``jit``/``shard_map``
(core, comm, dist, models, kernels, optim, and ``train/step.py``); launch,
configs, serve drivers and the training loop run host-side by design.

Flags, inside function bodies:

- ``x.item()`` — host sync; always wrong in library/step code.
- ``float(...)``/``int(...)``/``bool(...)`` over an expression that calls
  into ``jnp.*`` / ``jax.lax.*`` / ``jax.random.*`` — concretizes a tracer.
  (Static helpers like ``jnp.dtype`` are exempt.)
- ``if``/``while``/``assert`` whose test calls into jnp/lax — Python
  control flow on a traced value raises ``TracerBoolConversionError`` at
  best and silently specializes at worst.
- a curated set of ``np.*`` array ops (``np.asarray``, ``np.sum``, ...) —
  host numpy over a tracer fails; static shape helpers (``np.ndim``,
  ``np.prod`` over shapes) stay allowed.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.findings import Finding

from ._common import ScopedVisitor, attr_chain

TRACED_SCOPES = (
    "repro/core/", "repro/comm/", "repro/dist/", "repro/models/",
    "repro/kernels/", "repro/optim/", "repro/train/step.py",
)

# jnp/jax attributes that are static (operate on dtypes/shapes, not values)
_STATIC_ATTRS = frozenset(
    {"dtype", "shape", "ndim", "size", "itemsize", "eval_shape",
     "ShapeDtypeStruct", "tree", "tree_util"}
)

# np.<name> calls that consume array *values* (host-side math)
_NP_VALUE_OPS = frozenset(
    {"asarray", "array", "copy", "sum", "mean", "max", "min", "abs", "exp",
     "log", "sqrt", "dot", "matmul", "where", "argmax", "argmin", "argsort",
     "linalg", "concatenate", "stack", "einsum"}
)


def _is_traced_call(node: ast.AST) -> bool:
    """Does ``node`` contain a call into jnp / jax.lax / jax.random?"""
    for n in ast.walk(node):
        if not isinstance(n, ast.Call):
            continue
        chain = attr_chain(n.func)
        if len(chain) < 2:
            continue
        if chain[0] == "jnp" and chain[1] not in _STATIC_ATTRS:
            return True
        if chain[0] == "jax" and len(chain) >= 2 and chain[1] in (
            "lax", "random", "numpy", "nn"
        ):
            return True
        if chain[0] == "lax":
            return True
    return False


class _Visitor(ScopedVisitor):
    def __init__(self, ctx):
        super().__init__()
        self.ctx = ctx
        self.findings: List[Finding] = []
        self._depth = 0  # >0 inside a function body

    def _scoped(self, node, label):
        is_fn = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda))
        self._depth += is_fn
        super()._scoped(node, label)
        self._depth -= is_fn

    def _flag(self, node, msg):
        self.findings.append(
            self.ctx.finding("tracer-leak", node, self.qualname, msg)
        )

    def visit_Call(self, node):  # noqa: N802
        if self._depth:
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                self._flag(node, ".item() syncs to host; traced code must "
                                 "stay device-side")
            chain = attr_chain(node.func)
            if (len(chain) == 1 and chain[0] in ("float", "int", "bool")
                    and node.args and _is_traced_call(node.args[0])):
                self._flag(node, f"{chain[0]}() over a jnp/lax expression "
                                 "concretizes a tracer")
            if (len(chain) >= 2 and chain[0] == "np"
                    and chain[1] in _NP_VALUE_OPS):
                self._flag(node, f"host numpy op np.{chain[1]} in traced "
                                 "code; use jnp (np is only safe on static "
                                 "shapes/dtypes)")
        self.generic_visit(node)

    def _check_test(self, node, kind):
        if self._depth and _is_traced_call(node.test):
            self._flag(node, f"Python {kind} on a jnp/lax value; use "
                             "jnp.where / lax.cond instead of host control "
                             "flow on tracers")

    def visit_If(self, node):  # noqa: N802
        self._check_test(node, "branch")
        self.generic_visit(node)

    def visit_While(self, node):  # noqa: N802
        self._check_test(node, "loop")
        self.generic_visit(node)

    def visit_Assert(self, node):  # noqa: N802
        self._check_test(node, "assert")
        self.generic_visit(node)

    def visit_IfExp(self, node):  # noqa: N802
        self._check_test(node, "conditional expression")
        self.generic_visit(node)


def check_tracer_leaks(ctx) -> List[Finding]:
    if not any(
        ctx.path.startswith(p) or ctx.path == p.rstrip("/")
        for p in TRACED_SCOPES
    ):
        return []
    v = _Visitor(ctx)
    v.visit(ctx.tree)
    return v.findings

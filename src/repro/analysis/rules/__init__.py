"""Lint rule registry.

An AST rule is a callable ``rule(ctx) -> list[Finding]`` where ``ctx`` is a
:class:`repro.analysis.lint.FileContext` (parsed tree + path + source).
Semantic rules (which import repro modules and check runtime registries
rather than source text) run once per sweep, not per file, and are listed
separately.
"""
from __future__ import annotations

from .axis_names import check_axis_names
from .collectives import check_dsize_collectives
from .registry import check_registry_consistency
from .tracer import check_tracer_leaks

# per-file AST rules: rule id -> callable(FileContext) -> [Finding]
AST_RULES = {
    "axis-name": check_axis_names,
    "tracer-leak": check_tracer_leaks,
    "dsize-collective": check_dsize_collectives,
}

# whole-repo semantic rules: rule id -> callable() -> [Finding]
SEMANTIC_RULES = {
    "registry-consistency": check_registry_consistency,
}

"""Rule ``registry-consistency``: compressors x layouts x bit counters agree.

Semantic (imports the live registries rather than parsing source): for every
compressor registered in ``repro.core.compressors._REGISTRY``,

- ``build_compressor`` must realize a known payload layout
  (``per_shard | per_tensor | flat | dense``);
- ``repro.comm.bits.account`` must cover it (a registered compressor with
  no ``bits_wire`` accounting is exactly the "hand-maintained counters
  diverge" failure mode this subsystem exists to prevent), and its wire
  bits must be positive and finite;
- the legacy ``topk_impl`` spellings ("sharded", "block") and
  ``bucket="global"`` must keep resolving through
  ``CompressorConfig.resolved_impl/resolved_layout`` (ROADMAP carried-over
  compatibility), and the explicit-layout conflict guard must still raise.
"""
from __future__ import annotations

import math
from typing import List

from repro.analysis.findings import Finding

_PATH = "repro/core/compressors.py"
_LAYOUTS = {"per_shard", "per_tensor", "flat", "dense"}
_IMPLS = {"exact", "reference", "kernel"}


def _finding(name: str, message: str, path: str = _PATH) -> Finding:
    return Finding(
        rule="registry-consistency", path=path, line=0,
        qualname="_REGISTRY", snippet=name, message=message,
    )


def check_registry_consistency(registry=None) -> List[Finding]:
    import jax.numpy as jnp

    from repro.comm import bits as bits_lib
    from repro.core import compressors as C

    registry = registry if registry is not None else C._REGISTRY
    findings: List[Finding] = []
    template = {"w": jnp.zeros((64, 8), jnp.float32),
                "b": jnp.zeros((32,), jnp.float32)}

    for name in sorted(registry):
        cfg = C.CompressorConfig(name=name)
        try:
            comp = C.build_compressor(cfg)
        except Exception as e:  # pragma: no cover - registry must build
            findings.append(_finding(
                name, f"registered compressor fails to build: {e!r}"))
            continue
        if comp.layout not in _LAYOUTS:
            findings.append(_finding(
                name, f"realized layout {comp.layout!r} is not one of "
                      f"{sorted(_LAYOUTS)}"))
        try:
            report = bits_lib.account(cfg, template)
            wire, paper = report.wire, report.paper
        except Exception as e:
            findings.append(_finding(
                name, "no bits_wire coverage in repro.comm.bits.account "
                      f"({e!r}); every registered compressor must be "
                      "accounted", path="repro/comm/bits.py"))
            continue
        if not (math.isfinite(wire) and wire > 0 and math.isfinite(paper)
                and paper > 0):
            findings.append(_finding(
                name, f"bits accounting degenerate (paper={paper}, "
                      f"wire={wire})", path="repro/comm/bits.py"))

    # legacy spelling resolution (only meaningful for the default registry)
    if registry is C._REGISTRY:
        for legacy in ("sharded", "block"):
            cfg = C.CompressorConfig(topk_impl=legacy)
            if cfg.resolved_impl() not in _IMPLS:
                findings.append(_finding(
                    f"topk_impl={legacy!r}",
                    f"legacy spelling resolves to unknown impl "
                    f"{cfg.resolved_impl()!r}"))
            if cfg.resolved_layout() not in _LAYOUTS:
                findings.append(_finding(
                    f"topk_impl={legacy!r}",
                    f"legacy spelling resolves to unknown layout "
                    f"{cfg.resolved_layout()!r}"))
        if C.CompressorConfig(bucket="global").resolved_layout() != "flat":
            findings.append(_finding(
                "bucket='global'",
                "legacy global bucket no longer resolves to the flat layout"))
        try:
            C.build_compressor(
                C.CompressorConfig(layout="per_shard", topk_impl="exact"))
        except ValueError:
            pass  # the documented conflict guard
        else:
            findings.append(_finding(
                "layout='per_shard', topk_impl='exact'",
                "conflicting layout/impl no longer rejected; silent layout "
                "switching breaks the wire accounting"))
    return findings

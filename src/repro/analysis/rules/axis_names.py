"""Rule ``axis-name``: no hardcoded collective axis names.

Every ``psum``/``all_gather``/``ppermute``/``axis_index``/``axis_size``/...
axis name must be *bound* — threaded in from the strategy
(`dist.strategy.Strategy`) or an enclosing ``shard_map`` parameter — never a
string literal at the collective call site. A literal axis name silently
breaks when `choose_strategy` renames/carves axes (e.g. the pipeline
``stage`` carve), and is invisible to the mesh-role bookkeeping.

A literal appearing as a *parameter default* (``def f(axis="stage")``) is
fine: the caller can rebind it, so the collective site itself stays generic.
"""
from __future__ import annotations

from typing import List

from repro.analysis.findings import Finding

from ._common import ScopedVisitor, axis_argument, collective_name, string_literals


class _Visitor(ScopedVisitor):
    def __init__(self, ctx):
        super().__init__()
        self.ctx = ctx
        self.findings: List[Finding] = []

    def visit_Call(self, node):  # noqa: N802
        name = collective_name(node)
        if name is not None:
            axis = axis_argument(node, name)
            if axis is not None and string_literals(axis):
                self.findings.append(self.ctx.finding(
                    "axis-name", node, self.qualname,
                    f"hardcoded axis name {string_literals(axis)!r} in "
                    f"lax.{name}; thread the axis from the strategy / "
                    "shard_map seam (a parameter default is fine)",
                ))
        self.generic_visit(node)


def check_axis_names(ctx) -> List[Finding]:
    v = _Visitor(ctx)
    v.visit(ctx.tree)
    return v.findings

"""Shared AST helpers for lint rules."""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

# jax.lax collectives that move *data* across devices (axis_index/axis_size
# are metadata queries: they take an axis name but move no payload)
DATA_COLLECTIVES = frozenset(
    {"psum", "pmean", "pmax", "pmin", "psum_scatter", "all_gather",
     "ppermute", "pshuffle", "all_to_all"}
)
AXIS_QUERIES = frozenset({"axis_index", "axis_size"})
COLLECTIVES = DATA_COLLECTIVES | AXIS_QUERIES

# argument slot of the axis name per collective (positional, 0-based)
_AXIS_ARG_POS = {name: 1 for name in DATA_COLLECTIVES}
_AXIS_ARG_POS.update({name: 0 for name in AXIS_QUERIES})
_AXIS_KWARGS = ("axis_name", "axis")


def attr_chain(node: ast.AST) -> Tuple[str, ...]:
    """('jax', 'lax', 'psum') for ``jax.lax.psum``; () when not a pure
    Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def collective_name(call: ast.Call) -> Optional[str]:
    """The collective's name if ``call`` invokes a jax/lax collective.

    Matches ``jax.lax.<op>``, ``lax.<op>``, and bare ``<op>`` imported from
    jax.lax (``from jax.lax import psum``) — the bare form only for names
    that are unambiguous collectives.
    """
    chain = attr_chain(call.func)
    if not chain:
        return None
    name = chain[-1]
    if name not in COLLECTIVES:
        return None
    root = chain[0]
    if len(chain) == 1:
        return name  # bare import; collective names are distinctive enough
    if root in ("jax", "lax"):
        return name
    return None


def axis_argument(call: ast.Call, name: str) -> Optional[ast.AST]:
    """The axis-name argument expression of a collective call, if present."""
    for kw in call.keywords:
        if kw.arg in _AXIS_KWARGS:
            return kw.value
    pos = _AXIS_ARG_POS.get(name)
    if pos is not None and len(call.args) > pos:
        return call.args[pos]
    return None


def string_literals(node: ast.AST) -> List[str]:
    """All string constants anywhere inside ``node``."""
    return [
        n.value for n in ast.walk(node)
        if isinstance(n, ast.Constant) and isinstance(n.value, str)
    ]


def is_numeric_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float, bool, complex))
    if isinstance(node, ast.UnaryOp) and isinstance(node.operand, ast.Constant):
        return isinstance(node.operand.value, (int, float, complex))
    return False


class ScopedVisitor(ast.NodeVisitor):
    """NodeVisitor that tracks the enclosing qualname (functions/classes)."""

    def __init__(self) -> None:
        self._stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self._stack) if self._stack else "<module>"

    def _scoped(self, node, label: str) -> None:
        self._stack.append(label)
        self.generic_visit(node)
        self._stack.pop()

    def visit_FunctionDef(self, node):       # noqa: N802 (ast API casing)
        self._scoped(node, node.name)

    def visit_AsyncFunctionDef(self, node):  # noqa: N802
        self._scoped(node, node.name)

    def visit_ClassDef(self, node):          # noqa: N802
        self._scoped(node, node.name)

    def visit_Lambda(self, node):            # noqa: N802
        self._scoped(node, "<lambda>")

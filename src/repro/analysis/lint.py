"""AST lint driver: parse every ``src/repro`` module, run the rules.

Rules see a :class:`FileContext` (parsed tree + repo-relative path) and
return :class:`~repro.analysis.findings.Finding` objects. Fingerprints are
content-derived (see ``findings.py``); an inline escape hatch exists for
single sites (``# repro-lint: ignore[rule-id]`` on the offending line) but
the committed baseline with a justification is the preferred mechanism —
it keeps all known exceptions in one reviewable place.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .findings import Finding

_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*ignore\[([\w,\s-]+)\]")


def default_root() -> str:
    """The ``src`` directory this installed/imported ``repro`` lives in."""
    pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return os.path.dirname(pkg)


@dataclass
class FileContext:
    path: str                     # repo-relative posix path ("repro/...")
    source: str
    tree: ast.AST
    lines: List[str]
    _counts: Dict[Tuple[str, str, str], int] = field(default_factory=dict)

    @classmethod
    def parse(cls, source: str, path: str) -> "FileContext":
        return cls(
            path=path.replace(os.sep, "/"),
            source=source,
            tree=ast.parse(source, filename=path),
            lines=source.splitlines(),
        )

    def _pragma_ignored(self, rule: str, lineno: int) -> bool:
        if 1 <= lineno <= len(self.lines):
            m = _PRAGMA_RE.search(self.lines[lineno - 1])
            if m:
                rules = {r.strip() for r in m.group(1).split(",")}
                return rule in rules or "all" in rules
        return False

    def finding(self, rule: str, node: ast.AST, qualname: str,
                message: str) -> Optional[Finding]:
        snippet = ast.unparse(node)
        key = (rule, qualname, snippet)
        occ = self._counts.get(key, 0)
        self._counts[key] = occ + 1
        lineno = getattr(node, "lineno", 0)
        if self._pragma_ignored(rule, lineno):
            return None
        return Finding(
            rule=rule, path=self.path, line=lineno, qualname=qualname,
            snippet=snippet, message=message, occurrence=occ,
        )


def _run_file_rules(ctx: FileContext) -> List[Finding]:
    from .rules import AST_RULES

    out: List[Finding] = []
    for rule_fn in AST_RULES.values():
        out.extend(f for f in rule_fn(ctx) if f is not None)
    return out


def lint_source(source: str, path: str = "repro/_snippet.py") -> List[Finding]:
    """Lint one source string (rule unit tests use this)."""
    return _run_file_rules(FileContext.parse(source, path))


def iter_python_files(root: Optional[str] = None):
    """Yield (abs_path, repo_relative_path) for every repro .py file,
    sorted for deterministic reports."""
    root = root or default_root()
    for dirpath, dirnames, filenames in sorted(os.walk(root)):
        dirnames.sort()
        if "__pycache__" in dirnames:
            dirnames.remove("__pycache__")
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                ap = os.path.join(dirpath, fn)
                yield ap, os.path.relpath(ap, root).replace(os.sep, "/")


def run_lint(root: Optional[str] = None,
             include_semantic: bool = True) -> List[Finding]:
    """Full lint sweep: per-file AST rules + whole-repo semantic rules."""
    findings: List[Finding] = []
    for abs_path, rel_path in iter_python_files(root):
        with open(abs_path, encoding="utf-8") as f:
            source = f.read()
        findings.extend(_run_file_rules(FileContext.parse(source, rel_path)))
    if include_semantic:
        from .rules import SEMANTIC_RULES

        for rule_fn in SEMANTIC_RULES.values():
            findings.extend(rule_fn())
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.occurrence))
    return findings


def report_rows(findings: List[Finding]) -> List[dict]:
    return [f.row() for f in findings]

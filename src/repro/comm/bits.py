"""Centralized bit accounting for the transport (paper Tables 1-3 inputs).

Two views per upload, both computed statically from an (abstract ok)
gradient template:

- ``paper``: the paper's 32-bits-per-transmitted-element convention
  (k elements for sparse compressors, d for dense ones, plus 32-bit
  per-bucket scalars where the method ships one, e.g. QSGD norms).
- ``wire``: what a real transport pays — value bits at ``wire_dtype`` width,
  index bits for sparse payloads (compact block-local u8/u16 when enabled),
  and per-bucket scalar overheads also at wire width.

Accounting is *per bucket* (one bucket per leaf for the per-tensor and
per-shard layouts, one global bucket for the flat layout), so the
layer-wise k-ratio schedule (``CompressorConfig.k_ratio_per_layer``,
Shi et al. 2019) is visible in the report: each bucket row carries its
effective k and realized compression ratio.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.core import topk as topk_lib
from repro.core.types import Tree, ceil_div, tree_size


def dtype_bits(name: str) -> int:
    return jnp.dtype(name).itemsize * 8


@dataclass(frozen=True)
class BucketBits:
    """One payload bucket's static accounting."""

    bucket: str          # "/"-joined leaf path ("__global__" for flat)
    size: int            # dense element count covered by the bucket
    k: int               # elements transmitted per upload (== size for dense)
    bits_paper: float
    bits_wire: float

    @property
    def ratio(self) -> float:
        return self.k / max(self.size, 1)


@dataclass(frozen=True)
class BitsReport:
    buckets: Tuple[BucketBits, ...]

    @property
    def paper(self) -> float:
        return float(sum(b.bits_paper for b in self.buckets))

    @property
    def wire(self) -> float:
        return float(sum(b.bits_wire for b in self.buckets))

    def rows(self) -> List[dict]:
        return [
            {
                "bucket": b.bucket, "size": b.size, "k": b.k,
                "k_ratio": b.ratio, "bits_paper": b.bits_paper,
                "bits_wire": b.bits_wire,
            }
            for b in self.buckets
        ]


def bucket_wire_bits(report: "BitsReport", prefixes) -> float:
    """Wire bits of the buckets under the given "/"-joined path prefixes.

    Used by the train step's pipeline accounting to size the stage-axis
    payload gather: the trunk buckets' wire bits ARE the payload bytes the
    k-sized stage all-gather moves (support-exact per_shard layout)."""

    def match(b: BucketBits) -> bool:
        return any(b.bucket == p or b.bucket.startswith(p + "/") for p in prefixes)

    return float(sum(b.bits_wire for b in report.buckets if match(b)))


def _leaves_with_paths(template: Tree):
    from repro.core.types import tree_flatten_with_paths

    paths, leaves, _ = tree_flatten_with_paths(template)
    return list(zip(paths, leaves))


def _index_bits(cfg, block_c: int) -> int:
    # single source of truth: the dtype the payload actually casts to
    from repro.core.compressors import index_dtype

    return jnp.dtype(index_dtype(cfg, block_c)).itemsize * 8


def _block_k(cfg, size: int, k: int, block: int) -> int:
    """Realized k under per-block rounding (blocked/flat-kernel impls)."""
    nb = ceil_div(size, block)
    return nb * min(max(1, ceil_div(k, nb)), block)


def _topk_buckets(cfg, template: Tree, leaf_specs, axis_sizes) -> List[BucketBits]:
    layout = cfg.resolved_layout()
    impl = cfg.resolved_impl()
    vb = dtype_bits(cfg.wire_dtype)

    if layout == "flat":
        d = tree_size(template)
        k = cfg.leaf_k(d)
        if impl in ("reference", "kernel"):
            k = _block_k(cfg, d, k, cfg.block_size)
        k = min(k, d)
        return [BucketBits("__global__", d, k, 32.0 * k, float(vb + 32) * k)]

    if layout == "per_tensor":
        out = []
        for path, x in _leaves_with_paths(template):
            k = cfg.leaf_k(x.size, path)
            if impl in ("reference", "kernel"):
                k = _block_k(cfg, x.size, k, cfg.block_size)
            k = min(k, x.size)
            out.append(BucketBits(path, x.size, k, 32.0 * k, float(vb + 32) * k))
        return out

    # per_shard: blocked view aligned to the leaf's sharded axis
    from repro.core.compressors import _blocked_kb, _sharded_axis_of, _spec_leaves

    specs = _spec_leaves(leaf_specs, template)
    out = []
    for (path, x), s in zip(_leaves_with_paths(template), specs):
        ax, axsz = _sharded_axis_of(s, x.shape, axis_sizes or {})
        blocked = topk_lib.blocked_view_shape(x.shape, ax, cfg.block_size, axsz)
        kb = _blocked_kb(cfg, x.shape, blocked, path=path)
        k_eff = (x.size // blocked[-1]) * kb
        ib = _index_bits(cfg, blocked[-1])
        out.append(
            BucketBits(path, x.size, k_eff, 32.0 * k_eff, float(vb + ib) * k_eff)
        )
    return out


def activation_payload_bits(
    wire_dtype: str, k_ratio: float, block_size: int, elems: int,
) -> float:
    """Static wire bits of ONE encoded activation block on the pipeline ring.

    The single source of truth shared by ``transport.ActivationLayout``
    (which emits exactly this payload), ``core.metrics.PipelineCommModel``
    (which multiplies it by the 1F1B hop count) and the HLO audit's analytic
    ring model. ``k_ratio <= 0`` is the dense cast: every element at
    ``wire_dtype`` width. Otherwise the block top-k payload: ``ceil(elems /
    block)`` blocks of ``kb = ceil(block * k_ratio)`` values each, values at
    ``wire_dtype`` plus block-local indices (u8 for blocks <= 256, u16 up to
    65536 — same compaction rule as the gradient payloads)."""
    vb = dtype_bits(wire_dtype)
    if k_ratio <= 0.0:
        return float(vb * elems)
    nb = ceil_div(elems, block_size)
    kb = min(max(1, math.ceil(block_size * k_ratio)), block_size)
    ib = 8 if block_size <= 256 else (16 if block_size <= 65536 else 32)
    return float(nb * kb * (vb + ib))


def kv_cache_bits_per_token(
    n_paged_layers: int,
    n_kv_heads: int,
    head_dim: int,
    cache_dtype: str,
    pos_bits: int = 32,
) -> float:
    """Stored bits per token slot across the serve engine's paged KV pools.

    One token slot holds a K row and a V row (n_kv_heads * head_dim values
    each) at the cache codec's wire dtype, plus one ``pos_bits`` position
    entry, per paged (global-attention) layer. The serve-side analogue of
    ``activation_payload_bits``: the single formula shared by the paged
    cache writes (``serve.paged_cache``), the engine's per-token cache-byte
    counters and BENCH_serve.json."""
    vb = dtype_bits(cache_dtype)
    return float(n_paged_layers) * (2.0 * n_kv_heads * head_dim * vb + pos_bits)


def account(
    cfg,
    template: Tree,
    leaf_specs=None,
    axis_sizes: Optional[dict] = None,
) -> BitsReport:
    """Static per-upload accounting for one compressor config.

    ``template`` is the full (un-stage-sliced) gradient tree the transport
    exchanges; abstract ShapeDtypeStructs are fine.
    """
    name = cfg.name
    vb = dtype_bits(cfg.wire_dtype)

    if name == "topk_ef":
        return BitsReport(tuple(_topk_buckets(cfg, template, leaf_specs, axis_sizes)))

    if name == "randk":
        if cfg.resolved_layout() == "flat":
            d = tree_size(template)
            k = min(cfg.leaf_k(d), d)
            return BitsReport(
                (BucketBits("__global__", d, k, 32.0 * k, float(vb + 32) * k),)
            )
        buckets = []
        for path, x in _leaves_with_paths(template):
            k = min(cfg.leaf_k(x.size, path), x.size)
            buckets.append(BucketBits(path, x.size, k, 32.0 * k, float(vb + 32) * k))
        return BitsReport(tuple(buckets))

    # dense transports: one bucket per leaf, every coordinate transmitted
    per_coord_paper, per_coord_wire, scalar_paper, scalar_wire = {
        # identity ships raw values: the wire pays the configured value dtype
        # (the old accounting hard-coded 32 — the wire_dtype fix)
        "identity": (32.0, float(vb), 0.0, 0.0),
        # qsgd ships log2(s)+1 bits per coordinate + one norm scalar per
        # bucket; the scalar is a value on the wire, so it pays wire_dtype
        "qsgd": (
            math.log2(cfg.qsgd_levels) + 1.0, math.log2(cfg.qsgd_levels) + 1.0,
            32.0, float(vb),
        ),
        "signsgd_ef": (1.0, 1.0, 32.0, float(vb)),
        "terngrad": (math.log2(3.0), math.log2(3.0), 32.0, float(vb)),
    }[name]
    buckets = []
    for path, x in _leaves_with_paths(template):
        buckets.append(
            BucketBits(
                path, x.size, x.size,
                per_coord_paper * x.size + scalar_paper,
                per_coord_wire * x.size + scalar_wire,
            )
        )
    return BitsReport(tuple(buckets))

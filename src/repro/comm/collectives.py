"""Collective primitives for compressed gradient exchange.

Maps the paper's PS uplink onto jax-native collectives (DESIGN.md §2):

- dense payloads  -> ``lax.psum`` over the worker axes (ring all-reduce).
- sparse payloads -> ``lax.all_gather`` of the fixed-k (values, indices)
  pairs over the worker axes, followed by a *local* scatter-add
  densification and 1/M mean. Per-chip wire bytes: M*k*(value+index) versus
  ~2*d*value for the dense ring — the paper's d -> k bit saving is
  structurally real on TPU.

All functions here run *inside* a partial-auto shard_map: the worker axes
(`pod`/`data`) are manual, the `model` axis is auto, so leaf tensors may be
TP-sharded and XLA keeps the scatter-add local to each model shard.

This module owns only the collectives; payload layout, densification
templates, and bit accounting live in :mod:`repro.comm.transport` /
:mod:`repro.comm.bits` (the ``Transport`` seam). Promoted here from the old
``repro.core.comm`` module, which remains as a deprecation shim.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.topk import BlockPayload, SparsePayload, _scatter_last
from repro.core.types import Tree


AxisNames = Sequence[str]


def dense_mean(tree: Tree, worker_axes: AxisNames) -> Tree:
    """psum-mean of a dense payload across workers."""
    return jax.tree.map(lambda x: jax.lax.pmean(x, tuple(worker_axes)), tree)


def pmean_tree(tree: Tree, axes: AxisNames) -> Tree:
    """Mean-reduce every leaf of ``tree`` over ``axes`` (identity when empty).

    The seam entry point for gradient/loss averaging over *reduce* axes
    (e.g. the intra-pod mean in hierarchical SASG). Lives here — not at the
    call site — so every d-sized reduction on the exchange path is owned by
    ``repro.comm`` and visible to the HLO collective audit.
    """
    axes = tuple(axes)
    if not axes:
        return tree
    return jax.tree.map(lambda x: jax.lax.pmean(x, axes), tree)


def psum_scalar(x: jax.Array, axes: AxisNames) -> jax.Array:
    """Sum a scalar statistic over ``axes`` (e.g. the |M^t| sender count).

    Scalar-only by contract: callers outside ``repro.comm`` must not psum
    array payloads directly (the dsize-collective lint rule enforces this).
    """
    return jax.lax.psum(x, tuple(axes))


def psum_tree(tree: Tree, axes: AxisNames) -> Tree:
    """Sum-reduce every leaf of ``tree`` over ``axes`` (identity when empty).

    The seam entry point for the *small* stage-axis reductions of the
    stage-local gradient path (dist.pipeline.build_stage_local_grads): only
    the prepare-side leaves (stem/embedding) cross this psum — adding exact
    zeros from the non-owning stages — so it is k-sized in spirit even
    though the leaves are dense. Owned here so the HLO audit sees it.
    """
    axes = tuple(axes)
    if not axes:
        return tree
    return jax.tree.map(lambda x: jax.lax.psum(x, axes), tree)


def stage_combine_leaf(x: jax.Array, axis: str, is_trunk: bool) -> jax.Array:
    """Dense stage-combine of one gradient leaf (the FALLBACK pipeline path).

    Trunk leaves are stage-sliced on dim 0 -> tiled all-gather restores the
    full stack; non-trunk grads exist only on the masked stage -> psum
    broadcasts them. d-sized over the stage axis by construction; the
    payload-level gather path (Transport.gather_payload) avoids this
    entirely for supported compressors. Relocated from
    ``dist.pipeline.build_stage_combine`` so every d-sized collective lives
    in the ``repro.comm`` seam.
    """
    if is_trunk:
        return jax.lax.all_gather(x, axis, axis=0, tiled=True)
    return jax.lax.psum(x, axis)


def gather_block_payload(p: BlockPayload, axis: str) -> BlockPayload:
    """Stage-gather a BlockPayload compressed from a stage-LOCAL trunk slice.

    Each stage owns a contiguous dim-0 slab of the stacked trunk leaf
    (param_specs shards dim 0 over ``stage``), and the block-local view
    never straddles the slab boundary (blocked_view_shape keeps dim 0 as a
    batch dim), so a tiled dim-0 all-gather of the k-sized (values, indices)
    payloads reconstructs EXACTLY the payload the flat run would have
    produced — this is the k-sized wire op that replaces the d-sized trunk
    all-gather. Indices are block-local and need no rebasing.
    """
    s = jax.lax.psum(1, axis)
    vals = jax.lax.all_gather(p.values, axis, axis=0, tiled=True)
    idxs = jax.lax.all_gather(p.indices, axis, axis=0, tiled=True)
    return BlockPayload(
        vals, idxs,
        (p.blocked_shape[0] * s,) + tuple(p.blocked_shape[1:]),
        (p.orig_shape[0] * s,) + tuple(p.orig_shape[1:]),
    )


def ring_shift_parts(parts: tuple, axis: str, perm) -> tuple:
    """ppermute every wire part of an encoded activation one hop around the
    stage ring (forward carries use the +1 ring, backward cotangent carries
    the -1 ring). The parts are whatever ``transport.ActivationLayout.encode``
    produced — the dense wire-dtype cast, or (values, indices) of the blocked
    top-k — so this is the ONLY shape the 1F1B ring ever moves. Owned by the
    ``repro.comm`` seam so the HLO audit attributes it as activation traffic
    by op type, not by shape exemption.
    """
    return tuple(jax.lax.ppermute(p, axis, perm) for p in parts)


def ring_broadcast_parts(parts: tuple, axis: str, mask) -> tuple:
    """Replicate encoded activation parts held by exactly one stage.

    ``mask`` is a traced bool, true only on the owning stage (the last stage
    for the finished-output broadcast); everywhere else the parts are
    zero-masked, so the psum is an exact broadcast of the owner's payload
    (adding zeros, no scaling). With the identity layout this is bitwise the
    GPipe ``psum(where(last, out, 0))``; with a compressed layout only the
    k-sized parts cross the wire and every stage decodes the SAME values.
    """
    return tuple(
        jax.lax.psum(jnp.where(mask, p, jnp.zeros_like(p)), axis)
        for p in parts
    )


def _is_payload(x) -> bool:
    return isinstance(x, (SparsePayload, BlockPayload))


def sparse_allgather_mean(payload: Tree, worker_axes: AxisNames, num_workers: int) -> Tree:
    """All-gather fixed-k sparse payloads across workers; densify locally.

    Returns the dense mean (1/M * sum_m densify(payload_m)):
    - SparsePayload leaves -> flat vectors (the transport reshapes them
      against its densify template);
    - BlockPayload leaves  -> leaf-shaped dense arrays; the densify scatter
      is shard-local (block axis aligned to the TP sharding) and the only
      cross-worker traffic is the k-sized payload gather. Accumulation loops
      over the (static, small) worker dim so the dense leaf is materialized
      exactly once, not M times.
    """
    axes = tuple(worker_axes)

    def leaf(p) -> jax.Array:
        vals = jax.lax.all_gather(p.values, axes, tiled=False)
        idxs = jax.lax.all_gather(p.indices, axes, tiled=False)
        if isinstance(p, SparsePayload):
            vals = vals.reshape(-1).astype(jnp.float32)
            idxs = idxs.reshape(-1).astype(jnp.int32)
            dense = jnp.zeros((p.size,), vals.dtype).at[idxs].add(vals, mode="drop")
            return dense / num_workers
        # BlockPayload: accumulate M shard-local scatters
        vals = vals.reshape((num_workers,) + p.values.shape)
        idxs = idxs.reshape((num_workers,) + p.indices.shape)
        dense = _scatter_last(
            vals[0].astype(jnp.float32), idxs[0].astype(jnp.int32), p.blocked_shape[-1]
        )
        for mi in range(1, num_workers):
            dense = dense + _scatter_last(
                vals[mi].astype(jnp.float32), idxs[mi].astype(jnp.int32),
                p.blocked_shape[-1],
            )
        return (dense / num_workers).reshape(p.orig_shape)

    return jax.tree.map(leaf, payload, is_leaf=_is_payload)


def exchange(payload: Tree, kind: str, worker_axes: AxisNames, num_workers: int) -> Tree:
    """Dispatch on compressor kind. Output: dense mean contribution tree.

    For sparse kinds, leaves come back as *flat* vectors; the caller reshapes
    against its densify template (payloads erase shape by design).
    """
    if kind == "dense":
        return dense_mean(payload, worker_axes)
    elif kind == "sparse":
        return sparse_allgather_mean(payload, worker_axes, num_workers)
    raise ValueError(f"unknown payload kind {kind!r}")


def reshape_like(flat_tree: Tree, template: Tree) -> Tree:
    """Reshape a tree of flat vectors to the template's leaf shapes/dtypes."""
    return jax.tree.map(
        lambda f, t: f[: t.size].reshape(t.shape).astype(t.dtype), flat_tree, template
    )

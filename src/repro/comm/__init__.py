"""repro.comm — the wire transport subsystem.

Layering (README "The repro.comm transport seam"):

    collectives  — worker-axis psum / fixed-k all-gather primitives
    bits         — centralized per-bucket bit accounting (paper + wire views)
    transport    — the Transport interface: layout x compressor x collectives
                   x stage composition x bit accounting

``repro.core.comm`` remains as a deprecation shim over ``collectives``.
"""
from .bits import BitsReport, BucketBits, account, dtype_bits
from .collectives import dense_mean, exchange, reshape_like, sparse_allgather_mean
from .transport import Transport, build_transport

__all__ = [
    "BitsReport", "BucketBits", "account", "dtype_bits",
    "dense_mean", "exchange", "reshape_like", "sparse_allgather_mean",
    "Transport", "build_transport",
]

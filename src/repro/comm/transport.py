"""The ``Transport`` seam: payload layout x compression x collectives.

The paper's bit savings come from what crosses the wire, so everything that
decides *wire shape* lives here, behind one interface:

    encode(state, g, key)   -> (payload, candidate_state)
    exchange(payload)       -> mean contribution (dense tree or flat vectors)
    densify(contrib, like)  -> full-shape fp32 update tree
    gather(g)               -> stage-combined full gradient tree
    bits_paper / bits_wire / bits_report   (centralized, repro.comm.bits)

Compressors (``repro.core.compressors``) only map values: they receive a
tree already laid out by the transport and return payload leaves + candidate
error-feedback state. The transport owns:

- **layout** (``per_shard | per_tensor | flat``): whether leaves are
  compressed on their shard-aligned blocked view, as per-leaf flat vectors,
  or as one concatenated global vector (the paper-exact T_k);
- **densification templates**: ``densify`` reshapes against the caller's
  full *gradient* tree, never against the raw params tree — under pipeline
  parallelism the in-region params have a stage-SLICED trunk, which is
  exactly why the old per-compressor densify paths could not compose with
  pipelining (the deleted ``train/step.py`` guard);
- **stage composition**: on the default hot path (block-local per_shard
  topk_ef) the transport is handed a ``StageInfo`` and compresses the
  stage-LOCAL trunk slice, then ``gather_payload`` all-gathers only the
  k-sized (values, indices) payload over the stage axis — the d-sized trunk
  gather never happens, and ``diff_sq_norm`` gives the selection rule a
  stage-psum'd norm so all stages agree on send/skip. Compressors whose
  support depends on cross-slice state fall back to the dense per-stage
  gradient combine (``dist.pipeline.build_stage_combine``), threaded in as
  ``grad_combine`` and applied by ``gather``;
- **bit accounting**: per-bucket paper/wire bits, wire-dtype aware,
  reporting the per-layer k-ratio schedule (``bits_report``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.compressors import CompressorConfig, CompressorDef, build_compressor
from repro.core.topk import BlockPayload, _scatter_last
from repro.core.types import (
    Tree,
    ceil_div,
    tree_cast,
    tree_flatten_concat,
    tree_flatten_with_paths,
    tree_unflatten_concat,
    tree_zeros_like,
)

from . import bits as bits_lib
from . import collectives


@dataclass(frozen=True)
class ActivationLayout:
    """Wire layout for stage-boundary activations on the pipeline ring.

    The gradient exchange owns its payload layout via the compressor configs;
    this is the analogous knob for the 1F1B activation ring (forward carries,
    backward cotangent carries, and the finished-output broadcast). Owned by
    the transport layer so ``encode``/``decode`` and the bit accounting
    (``payload_bits`` == ``bits.activation_payload_bits``) cannot drift apart.

    - default (fp32, ``k_ratio=0``): identity — ``encode`` returns the array
      unchanged and the ring is bit-identical to the uncompressed schedule.
    - ``wire_dtype="bfloat16"``: cast-on-the-wire; decode casts back to the
      compute dtype.
    - ``k_ratio > 0``: blocked top-k over the flattened activation (blocks of
      ``block_size``, ``kb = ceil(block_size * k_ratio)`` kept per block),
      values at ``wire_dtype`` + block-local u8/u16 indices — the same
      payload shape family as the gradient compressors, so the bit counters
      share one formula. Lossy: backward runs against the decoded forward
      activations, so the 1F1B engine still computes a consistent (exact
      gradient of the compressed forward) update.
    """

    wire_dtype: str = "float32"
    k_ratio: float = 0.0
    block_size: int = 256

    @property
    def is_identity(self) -> bool:
        return self.k_ratio <= 0.0 and jnp.dtype(self.wire_dtype) == jnp.float32

    def _kb(self) -> int:
        return min(max(1, math.ceil(self.block_size * self.k_ratio)),
                   self.block_size)

    def _index_dtype(self):
        if self.block_size <= 256:
            return jnp.uint8
        if self.block_size <= 65536:
            return jnp.uint16
        return jnp.int32

    def payload_bits(self, elems: int) -> float:
        """Wire bits of one encoded activation of ``elems`` elements."""
        return bits_lib.activation_payload_bits(
            self.wire_dtype, self.k_ratio, self.block_size, elems
        )

    def encode(self, x: jax.Array) -> tuple:
        """Activation -> tuple of wire arrays (the ring moves these parts)."""
        if self.k_ratio <= 0.0:
            return (x.astype(self.wire_dtype),)
        flat = x.reshape(-1)
        nb = ceil_div(flat.size, self.block_size)
        pad = nb * self.block_size - flat.size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(nb, self.block_size)
        # encode runs on device-LOCAL blocks inside the pipeline's manual
        # shard_map region, so lax.top_k's sort-partitioner caveat (the
        # reason topk.blocked_topk unrolls masked argmax) doesn't apply —
        # and one sort pass is far cheaper than kb argmax sweeps. Ties
        # resolve identically (descending |x|, first index wins).
        _, idx = jax.lax.top_k(jnp.abs(blocks), self._kb())
        vals = jnp.take_along_axis(blocks, idx, axis=-1)
        return (
            vals.astype(self.wire_dtype),
            idx.astype(self._index_dtype()),
        )

    def decode(self, parts: tuple, shape: tuple,
               dtype=jnp.float32) -> jax.Array:
        """Wire parts -> dense activation of ``shape`` (static)."""
        if self.k_ratio <= 0.0:
            return parts[0].astype(dtype)
        vals, idxs = parts
        dense = _scatter_last(
            vals.astype(jnp.float32), idxs.astype(jnp.int32), self.block_size
        )
        n = 1
        for d in shape:
            n *= d
        return dense.reshape(-1)[:n].reshape(shape).astype(dtype)


class StageInfo(NamedTuple):
    """Pipeline-stage context for the payload-level gather path.

    ``trunk_prefixes`` are "/"-joined params-tree path prefixes of the
    stage-sharded trunk leaves; ``trunk_dims`` maps each trunk leaf's full
    path to its FULL (unsliced) leading-dim size so the compressor can pick
    the as-if-full per-block k on the stage-local slice.
    """

    axis: str
    num_stages: int
    trunk_prefixes: tuple
    trunk_dims: dict


def supports_stage_payload(cfg: CompressorConfig) -> bool:
    """True iff the compressor can encode a stage-local trunk slice whose
    gathered payload is bit-identical to compressing the full leaf: the
    block-local per_shard top-k is support-exact (blocks never straddle the
    stage-slice boundary); every other layout/compressor sees cross-slice
    state (global or per-leaf top-k support, per-leaf norms, full-leaf
    randomness) and must use the dense stage-combine fallback."""
    return cfg.name == "topk_ef" and cfg.resolved_layout() == "per_shard"


def _is_trunk_path(path: str, prefixes) -> bool:
    return any(path == p or path.startswith(p + "/") for p in prefixes)


class Transport:
    """One built wire transport for a (compressor, mesh role) pair."""

    def __init__(
        self,
        cfg: CompressorConfig,
        worker_axes: Sequence[str],
        num_workers: int,
        leaf_specs=None,
        axis_sizes: Optional[dict] = None,
        grad_combine: Optional[Callable[[Tree], Tree]] = None,
        stage: Optional[StageInfo] = None,
        act_layout: Optional[ActivationLayout] = None,
    ):
        self.cfg = cfg
        self.worker_axes = tuple(worker_axes)
        self.num_workers = num_workers
        self.leaf_specs = leaf_specs
        self.axis_sizes = axis_sizes or {}
        self.grad_combine = grad_combine
        self.stage = stage
        self.act_layout = act_layout or ActivationLayout()
        if stage is not None and not supports_stage_payload(cfg):
            raise ValueError(
                f"compressor {cfg.name!r} (layout {cfg.resolved_layout()!r}) "
                "cannot take the payload-level stage gather path; use the "
                "dense grad_combine fallback instead"
            )
        self.compressor: CompressorDef = build_compressor(
            cfg, leaf_specs=leaf_specs, axis_sizes=axis_sizes,
            stage_dims=stage.trunk_dims if stage is not None else None,
        )
        self.kind = self.compressor.kind      # "sparse" | "dense"
        # the REALIZED layout: compressors without a blocked impl (randk)
        # realize per_shard configs as per_tensor flat vectors
        self.layout = self.compressor.layout

    # -- layout -------------------------------------------------------------

    def _lay_out(self, tree: Tree) -> Tree:
        """Apply the wire layout to a full-shape tree (flat = one global
        pseudo-leaf; other layouts keep the tree structure and let the
        compressor view each leaf)."""
        if self.layout == "flat":
            return {"__global__": tree_flatten_concat(tree)}
        return tree

    # -- stage composition ---------------------------------------------------

    def gather(self, g: Tree) -> Tree:
        """Combine per-stage gradient slices into the full tree the exchange
        operates on (identity when no pipeline stage axis is threaded in).

        On the payload path (``stage`` set, ``grad_combine`` None) this stays
        the identity: gradients remain stage-sliced and only the k-sized
        payload crosses the stage axis (``gather_payload``)."""
        if self.grad_combine is None:
            return g
        return self.grad_combine(g)

    def gather_payload(self, payload: Tree) -> Tree:
        """All-gather the k-sized trunk payload slices over the stage axis.

        The payload-level replacement for the d-sized trunk gather: trunk
        BlockPayload leaves (compressed from the stage-local slice) are
        dim-0 tiled-gathered into the full-stack payload; non-trunk payloads
        were computed from replicated grads and are already bit-identical
        across stages, so they pass through with zero collectives. Identity
        when no stage is threaded in."""
        if self.stage is None:
            return payload
        axis = self.stage.axis
        prefixes = self.stage.trunk_prefixes
        paths, leaves, treedef = tree_flatten_with_paths(
            payload, is_leaf=collectives._is_payload
        )
        out = [
            collectives.gather_block_payload(p, axis)
            if isinstance(p, BlockPayload) and _is_trunk_path(path, prefixes)
            else p
            for path, p in zip(paths, leaves)
        ]
        return jax.tree.unflatten(treedef, out)

    def diff_sq_norm(self, a: Tree, b: Tree) -> jax.Array:
        """Stage-aware ||a - b||^2 for the SASG/LASG selection rule.

        Trunk leaves are stage-local slices, so their squared-norm
        contribution is psum'd over the stage axis (a scalar — O(1) wire);
        non-trunk leaves are replicated and summed locally. All stages
        compute the same value, so the send decision agrees bitwise."""
        paths, la, _ = tree_flatten_with_paths(a)
        lb = jax.tree.leaves(b)
        trunk = jnp.zeros((), jnp.float32)
        local = jnp.zeros((), jnp.float32)
        for path, xa, xb in zip(paths, la, lb):
            d = xa.astype(jnp.float32) - xb.astype(jnp.float32)
            sq = jnp.sum(jnp.square(d))
            if self.stage is not None and _is_trunk_path(path, self.stage.trunk_prefixes):
                trunk = trunk + sq
            else:
                local = local + sq
        if self.stage is not None:
            trunk = collectives.psum_scalar(trunk, (self.stage.axis,))
        return local + trunk

    # -- encode / exchange / densify ----------------------------------------

    def init_state(self, params: Tree) -> Tree:
        """Compressor state (error-feedback buffers) for the wire layout."""
        return self.compressor.init(self._lay_out(params))

    def zero_payload(self, params: Tree) -> Tree:
        """Payload-shaped zeros: compress a zero tree (values come out 0)."""
        zeros = tree_zeros_like(params, dtype=jnp.float32)
        payload, _ = self.encode(self.init_state(zeros), zeros, jax.random.PRNGKey(0))
        return payload

    def encode(self, state: Tree, g: Tree, key) -> tuple:
        """Lay out the (full-shape) quantity tree and compress it.

        Returns (payload, candidate_state); the caller commits or discards
        the candidate state with the send/skip decision.
        """
        payload, cand = self.compressor.compress(state, self._lay_out(g), key)
        return payload, cand

    def exchange(self, payload: Tree) -> Tree:
        """Worker-axis collective: psum-mean for dense payloads, fixed-k
        all-gather + local scatter-add mean for sparse ones."""
        return collectives.exchange(
            payload, self.kind, self.worker_axes, self.num_workers
        )

    def exchange_overlapped(
        self, fresh: Tree, stale: Tree, cand_state: Tree, old_state: Tree,
        send, like: Tree,
    ) -> tuple:
        """Per-bucket select -> dispatch with a double-buffered EF commit.

        The synchronous path selects the WHOLE payload tree (fresh vs the
        stale cache), commits the EF state, then hands one monolithic tree to
        the worker collective — every bucket's collective therefore depends
        on every bucket's select in the emitted dataflow. Here each payload
        bucket is selected and dispatched to its worker collective
        independently, so XLA's latency-hiding scheduler may launch a
        bucket's all-gather as soon as ITS gradient leaf (and the scalar send
        bit) is ready, overlapping the remaining buckets' backward compute.
        The EF state is double-buffered: the candidate buffer from ``encode``
        is held alongside the old one until all bucket dispatches are
        emitted, then committed with the same send bit — the commit is moved
        AFTER the collectives in the dataflow, but selects between the same
        two buffers, so the committed state (and the update) is bit-identical
        to the synchronous path (tests/test_overlap_exchange.py).

        ``send=None`` means selection is statically off (always-send): the
        per-bucket where-gates vanish entirely and each bucket's collective
        depends only on its own gradient leaf. The flat layout has a single
        global bucket, so per-bucket == whole-tree there.

        Dense-kind payloads (qsgd / signsgd / terngrad / identity) keep the
        monolithic dispatch: their exchange is a summing psum, and splitting
        it per bucket lets XLA's all-reduce combiner regroup the reductions
        into a different elementwise summation order (ulp-level drift vs the
        sync path). Sparse kinds are all-gathers — order-free — so only they
        gain (and stay bit-exact under) per-bucket dispatch.

        Returns ``(update, payload_committed, comp_state_committed)``.
        """
        from repro.core.types import tree_where

        monolithic = self.layout == "flat" or self.kind == "dense"
        if send is None:
            sel_payload, new_state = fresh, cand_state
        elif monolithic:
            sel_payload = tree_where(send, fresh, stale)
            new_state = tree_where(send, cand_state, old_state)
        else:
            fpaths, fleaves, ftdef = tree_flatten_with_paths(
                fresh, is_leaf=collectives._is_payload
            )
            _, sleaves, _ = tree_flatten_with_paths(
                stale, is_leaf=collectives._is_payload
            )
            sel_payload = jax.tree.unflatten(ftdef, [
                tree_where(send, pf, ps) for pf, ps in zip(fleaves, sleaves)
            ])
            new_state = tree_where(send, cand_state, old_state)
        if monolithic or send is None:
            contrib = self.exchange(sel_payload)
        else:
            spaths, sleaves2, stdef = tree_flatten_with_paths(
                sel_payload, is_leaf=collectives._is_payload
            )
            contrib = jax.tree.unflatten(stdef, [
                collectives.exchange(
                    p, self.kind, self.worker_axes, self.num_workers
                )
                for p in sleaves2
            ])
        return self.densify(contrib, like), sel_payload, new_state

    def densify(self, contrib: Tree, like: Tree) -> Tree:
        """Reshape the exchanged mean contribution against ``like`` — the
        full gradient tree (NOT the possibly stage-sliced params tree).
        Sparse layouts come back fp32; dense contributions pass through."""
        if self.kind == "dense":
            return contrib
        if self.layout == "flat":
            update = tree_unflatten_concat(contrib["__global__"], like)
            return tree_cast(update, jnp.float32)
        if self.layout == "per_shard":
            # BlockPayload densify already restored leaf shapes
            return tree_cast(contrib, jnp.float32)
        # per_tensor: flat vectors per leaf
        return collectives.reshape_like(contrib, tree_cast(like, jnp.float32))

    # -- bit accounting ------------------------------------------------------

    def bits_report(self, template: Tree) -> bits_lib.BitsReport:
        return bits_lib.account(
            self.cfg, template, leaf_specs=self.leaf_specs,
            axis_sizes=self.axis_sizes,
        )

    def bits_paper(self, template: Tree) -> float:
        return self.bits_report(template).paper

    def bits_wire(self, template: Tree) -> float:
        return self.bits_report(template).wire


def build_transport(
    cfg: CompressorConfig,
    worker_axes: Sequence[str],
    num_workers: int,
    leaf_specs=None,
    axis_sizes: Optional[dict] = None,
    grad_combine: Optional[Callable[[Tree], Tree]] = None,
    stage: Optional[StageInfo] = None,
    act_layout: Optional["ActivationLayout"] = None,
) -> Transport:
    return Transport(
        cfg, worker_axes, num_workers,
        leaf_specs=leaf_specs, axis_sizes=axis_sizes, grad_combine=grad_combine,
        stage=stage, act_layout=act_layout,
    )

"""The ``Transport`` seam: payload layout x compression x collectives.

The paper's bit savings come from what crosses the wire, so everything that
decides *wire shape* lives here, behind one interface:

    encode(state, g, key)   -> (payload, candidate_state)
    exchange(payload)       -> mean contribution (dense tree or flat vectors)
    densify(contrib, like)  -> full-shape fp32 update tree
    gather(g)               -> stage-combined full gradient tree
    bits_paper / bits_wire / bits_report   (centralized, repro.comm.bits)

Compressors (``repro.core.compressors``) only map values: they receive a
tree already laid out by the transport and return payload leaves + candidate
error-feedback state. The transport owns:

- **layout** (``per_shard | per_tensor | flat``): whether leaves are
  compressed on their shard-aligned blocked view, as per-leaf flat vectors,
  or as one concatenated global vector (the paper-exact T_k);
- **densification templates**: ``densify`` reshapes against the caller's
  full *gradient* tree, never against the raw params tree — under pipeline
  parallelism the in-region params have a stage-SLICED trunk, which is
  exactly why the old per-compressor densify paths could not compose with
  pipelining (the deleted ``train/step.py`` guard);
- **stage composition**: the per-stage gradient combine (trunk all-gather +
  stage-0-masked psum, built by ``dist.pipeline.build_stage_combine``) is
  threaded in as ``grad_combine`` and applied by ``gather`` — the transport,
  not ``build_pipelined_vag``, decides what the exchange sees;
- **bit accounting**: per-bucket paper/wire bits, wire-dtype aware,
  reporting the per-layer k-ratio schedule (``bits_report``).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.compressors import CompressorConfig, CompressorDef, build_compressor
from repro.core.types import (
    Tree,
    tree_cast,
    tree_flatten_concat,
    tree_unflatten_concat,
    tree_zeros_like,
)

from . import bits as bits_lib
from . import collectives


class Transport:
    """One built wire transport for a (compressor, mesh role) pair."""

    def __init__(
        self,
        cfg: CompressorConfig,
        worker_axes: Sequence[str],
        num_workers: int,
        leaf_specs=None,
        axis_sizes: Optional[dict] = None,
        grad_combine: Optional[Callable[[Tree], Tree]] = None,
    ):
        self.cfg = cfg
        self.worker_axes = tuple(worker_axes)
        self.num_workers = num_workers
        self.leaf_specs = leaf_specs
        self.axis_sizes = axis_sizes or {}
        self.grad_combine = grad_combine
        self.compressor: CompressorDef = build_compressor(
            cfg, leaf_specs=leaf_specs, axis_sizes=axis_sizes
        )
        self.kind = self.compressor.kind      # "sparse" | "dense"
        # the REALIZED layout: compressors without a blocked impl (randk)
        # realize per_shard configs as per_tensor flat vectors
        self.layout = self.compressor.layout

    # -- layout -------------------------------------------------------------

    def _lay_out(self, tree: Tree) -> Tree:
        """Apply the wire layout to a full-shape tree (flat = one global
        pseudo-leaf; other layouts keep the tree structure and let the
        compressor view each leaf)."""
        if self.layout == "flat":
            return {"__global__": tree_flatten_concat(tree)}
        return tree

    # -- stage composition ---------------------------------------------------

    def gather(self, g: Tree) -> Tree:
        """Combine per-stage gradient slices into the full tree the exchange
        operates on (identity when no pipeline stage axis is threaded in)."""
        if self.grad_combine is None:
            return g
        return self.grad_combine(g)

    # -- encode / exchange / densify ----------------------------------------

    def init_state(self, params: Tree) -> Tree:
        """Compressor state (error-feedback buffers) for the wire layout."""
        return self.compressor.init(self._lay_out(params))

    def zero_payload(self, params: Tree) -> Tree:
        """Payload-shaped zeros: compress a zero tree (values come out 0)."""
        zeros = tree_zeros_like(params, dtype=jnp.float32)
        payload, _ = self.encode(self.init_state(zeros), zeros, jax.random.PRNGKey(0))
        return payload

    def encode(self, state: Tree, g: Tree, key) -> tuple:
        """Lay out the (full-shape) quantity tree and compress it.

        Returns (payload, candidate_state); the caller commits or discards
        the candidate state with the send/skip decision.
        """
        payload, cand = self.compressor.compress(state, self._lay_out(g), key)
        return payload, cand

    def exchange(self, payload: Tree) -> Tree:
        """Worker-axis collective: psum-mean for dense payloads, fixed-k
        all-gather + local scatter-add mean for sparse ones."""
        return collectives.exchange(
            payload, self.kind, self.worker_axes, self.num_workers
        )

    def densify(self, contrib: Tree, like: Tree) -> Tree:
        """Reshape the exchanged mean contribution against ``like`` — the
        full gradient tree (NOT the possibly stage-sliced params tree).
        Sparse layouts come back fp32; dense contributions pass through."""
        if self.kind == "dense":
            return contrib
        if self.layout == "flat":
            update = tree_unflatten_concat(contrib["__global__"], like)
            return tree_cast(update, jnp.float32)
        if self.layout == "per_shard":
            # BlockPayload densify already restored leaf shapes
            return tree_cast(contrib, jnp.float32)
        # per_tensor: flat vectors per leaf
        return collectives.reshape_like(contrib, tree_cast(like, jnp.float32))

    # -- bit accounting ------------------------------------------------------

    def bits_report(self, template: Tree) -> bits_lib.BitsReport:
        return bits_lib.account(
            self.cfg, template, leaf_specs=self.leaf_specs,
            axis_sizes=self.axis_sizes,
        )

    def bits_paper(self, template: Tree) -> float:
        return self.bits_report(template).paper

    def bits_wire(self, template: Tree) -> float:
        return self.bits_report(template).wire


def build_transport(
    cfg: CompressorConfig,
    worker_axes: Sequence[str],
    num_workers: int,
    leaf_specs=None,
    axis_sizes: Optional[dict] = None,
    grad_combine: Optional[Callable[[Tree], Tree]] = None,
) -> Transport:
    return Transport(
        cfg, worker_axes, num_workers,
        leaf_specs=leaf_specs, axis_sizes=axis_sizes, grad_combine=grad_combine,
    )

"""repro: SASG (sparse + adaptive stochastic gradient) distributed-training
framework in JAX. See DESIGN.md for the system inventory."""
__version__ = "0.1.0"

"""repro: SASG (sparse + adaptive stochastic gradient) distributed-training
framework in JAX. See DESIGN.md for the system inventory."""
from . import compat as _compat  # noqa: F401  (installs JAX version shims)

__version__ = "0.1.0"

"""Gradient compressors: the paper's top-k + error feedback, and baselines.

A compressor is a pure-functional pair (init, compress) packaged as a
``CompressorDef``. Compression always receives the already gamma-folded
quantity ``g = lr * grad + error`` (paper eq. 8's g_m^t); error feedback
state is owned by the compressor and updated *candidately*: the caller
(sasg.py) commits or discards the candidate state depending on the adaptive
send/skip decision.

Compressors only **map values**: the tree they receive is already laid out
for the wire by the transport (``repro.comm.transport``), which owns the
layout policy (``per_shard | per_tensor | flat``), the collectives, the
densification templates, and all bit accounting (``repro.comm.bits``).

Kinds:
- ``sparse``: payload is a pytree of SparsePayload / BlockPayload leaves
  (fixed-k values+indices); exchanged with a worker-axis all-gather then
  local scatter-add (repro.comm.collectives).
- ``dense``: payload is a dense tree (possibly quantize-dequantized values);
  exchanged with a plain psum. Bit accounting still reflects the encoded
  width (e.g. 1 bit/coord for signSGD), because on a real transport the
  encoded form is what crosses the wire.

Implemented:
  identity     — distributed SGD / LASG transport (32d bits per upload)
  topk_ef      — paper's T_k with error feedback (32k bits) [SASG/Sparse]
  randk        — unbiased random-k (Wangni et al., 2018)
  qsgd         — QSGD stochastic quantization (Alistarh et al., 2017)
  signsgd_ef   — 1-bit sign with error feedback (Karimireddy et al., 2019)
  terngrad     — ternary stochastic quantization (Wen et al., 2017)

``topk_ef``'s per-shard layout defaults to the fused Pallas EF+top-k kernel
(``repro.kernels.topk_ef``; interpret-mode on CPU, real Pallas on TPU) with
the unfused blocked operator kept as ``topk_impl="reference"`` — under the
default fp32 ``error_dtype`` both are bit-identical (same iterative
masked-argmax selection, same tie-breaks; property-tested in
tests/test_comm_transport.py). With a narrower ``error_dtype`` the kernel
accumulates the EF correction in fp32 and rounds once at the end, while the
reference adds in ``error_dtype`` — equally valid EF semantics, but
near-tied selections can differ between the two impls.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import topk as topk_lib
from .types import Tree, tree_flatten_with_paths, tree_zeros_like

_LEGACY_IMPLS = {"sharded": "reference", "block": "reference"}


@dataclass(frozen=True)
class CompressorConfig:
    name: str = "topk_ef"
    k_ratio: float = 0.01          # paper uses top-1% (k = 0.01 d)
    # Layer-wise adaptive sparsification (Shi et al., 2019): ordered
    # (path_substring, ratio) pairs matched against the leaf's "/"-joined
    # tree path; first match wins, k_ratio is the fallback. The flat layout
    # has a single global bucket and ignores the schedule.
    k_ratio_per_layer: Tuple[Tuple[str, float], ...] = ()
    # block granularity: the per-shard impls select kb=ceil(k_ratio*block)
    # per block via iterative argmax, so smaller blocks keep the iteration
    # count low (256 -> kb=3 at 1%); the flat impls use bigger blocks.
    block_size: int = 256
    # Wire layout — owned by the transport (repro.comm.transport):
    #   "per_shard":  shard-aligned blocked view of each leaf in its natural
    #                 layout — zero resharding, the production default.
    #   "per_tensor": flat vector per leaf.
    #   "flat":       one concatenated global vector (paper-exact T_k).
    #   "" (auto):    per_shard unless a legacy topk_impl spelling implies
    #                 otherwise. An EXPLICIT layout always wins — a
    #                 conflicting impl (layout="per_shard", topk_impl=
    #                 "exact") errors in make_topk_ef instead of silently
    #                 switching layouts.
    layout: str = ""
    # Selection impl within the layout:
    #   per_shard:         "kernel" (fused Pallas EF+top-k, the default)
    #                      | "reference" (unfused blocked_topk)
    #   per_tensor / flat: "exact" | "reference" (block-local) | "kernel"
    # Legacy aliases still resolve: "sharded" -> per_shard + reference,
    # "block" -> reference; "exact"/"block" imply the per_tensor layout.
    topk_impl: str = "kernel"
    bucket: str = "per_tensor"     # legacy: "global" -> layout="flat"
    wire_dtype: str = "float32"    # payload value dtype on the wire
    error_dtype: str = "float32"   # EF accumulator dtype
    # Beyond-paper (EXPERIMENTS.md §Perf iter 5): block-LOCAL indices fit in
    # u8/u16 for block_size <= 256/65536, shrinking payload wire bytes vs
    # the flat operator's mandatory 32-bit global indices.
    compact_indices: bool = False
    qsgd_levels: int = 256         # QSGD quantization levels (8-bit default)

    def resolved_layout(self) -> str:
        """Wire layout with the legacy bucket/topk_impl spellings folded in.

        Legacy spellings only steer the AUTO (``layout=""``) case; an
        explicitly configured layout is never overridden by them."""
        if self.bucket == "global":
            return "flat"
        if self.layout:
            return self.layout
        if self.topk_impl in ("exact", "block"):
            return "per_tensor"
        return "per_shard"

    def resolved_impl(self) -> str:
        return _LEGACY_IMPLS.get(self.topk_impl, self.topk_impl)

    def ratio_for(self, path: str = "") -> float:
        # the flat layout's single "__global__" pseudo-leaf is not a layer:
        # the layer-wise schedule never applies to it (doc above)
        if path != "__global__":
            for pattern, ratio in self.k_ratio_per_layer:
                if pattern and pattern in path:
                    return float(ratio)
        return self.k_ratio

    def leaf_k(self, size: int, path: str = "") -> int:
        return max(1, int(round(self.ratio_for(path) * size)))


class CompressorDef(NamedTuple):
    name: str
    kind: str    # "sparse" | "dense"
    # realized payload layout: "per_shard" | "per_tensor" | "flat" | "dense"
    # (randk has no blocked impl, so per_shard configs realize per_tensor)
    layout: str
    init: Callable[[Tree], Tree]
    # compress(state, g_tree, key) -> (payload_tree, candidate_state)
    compress: Callable[[Tree, Tree, Optional[jax.Array]], tuple[Any, Tree]]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def index_dtype(cfg: CompressorConfig, block_c: int):
    """On-wire index dtype for a payload bucket: block-LOCAL indices fit in
    u8/u16 when compact_indices is on; the single source of truth for both
    the payload cast (make_topk_ef) and the wire accounting (comm.bits)."""
    if not cfg.compact_indices:
        return jnp.int32
    if block_c <= 256:
        return jnp.uint8
    if block_c <= 65536:
        return jnp.uint16
    return jnp.int32


def _is_spec(s) -> bool:
    from jax.sharding import PartitionSpec

    return s is None or isinstance(s, PartitionSpec)


def _spec_leaves(leaf_specs, template) -> list:
    """Per-leaf PartitionSpecs aligned with ``template``'s flatten order
    (None-filled on structure mismatch or when no specs were provided)."""
    n = len(template) if isinstance(template, list) else len(jax.tree.leaves(template))
    if leaf_specs is None:
        return [None] * n
    specs = jax.tree.leaves(leaf_specs, is_leaf=_is_spec)
    return specs if len(specs) == n else [None] * n


def _sharded_axis_of(spec, shape, axis_sizes) -> tuple:
    """(axis_index_or_None, axis_size) of the last mesh-sharded leaf dim."""
    if spec is None:
        return None, 1
    found, size = None, 1
    for i, entry in enumerate(tuple(spec)[: len(shape)]):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        s = 1
        for n in names:
            s *= axis_sizes.get(n, 1)
        if s > 1:
            found, size = i, s
    return found, size


def _blocked_kb(cfg: CompressorConfig, shape: tuple, blocked: tuple,
                path: str = "") -> int:
    size = 1
    for d in shape:
        size *= d
    k = cfg.leaf_k(size, path)
    nblocks = size // blocked[-1]
    return min(max(1, -(-k // nblocks)), blocked[-1])


def _flat_topk(cfg: CompressorConfig, flat: jax.Array, k: int) -> topk_lib.SparsePayload:
    impl = cfg.resolved_impl()
    if impl == "exact":
        return topk_lib.exact_topk(flat, k)
    elif impl == "reference":
        return topk_lib.block_topk(flat, k, cfg.block_size)
    elif impl == "kernel":
        from repro.kernels.topk_ef import ops as kops  # lazy: optional dep

        return kops.block_topk(flat, k, cfg.block_size)
    raise ValueError(f"unknown topk_impl {cfg.topk_impl!r}")


# ---------------------------------------------------------------------------
# identity (SGD / LASG transport)
# ---------------------------------------------------------------------------

def make_identity(cfg: CompressorConfig) -> CompressorDef:
    wdtype = jnp.dtype(cfg.wire_dtype)

    def init(tree):
        return ()

    def compress(state, g, key):
        # wire emulation: values cross the transport at wire_dtype, so the
        # payload carries exactly that precision (round-tripped back to the
        # compute dtype for the psum) — keeps the realized exchange
        # consistent with the dtype-aware bits_wire accounting. No-op for
        # the default float32 wire.
        payload = jax.tree.map(
            lambda x: x.astype(wdtype).astype(x.dtype)
            if jnp.dtype(x.dtype) != wdtype else x,
            g,
        )
        return payload, state

    return CompressorDef("identity", "dense", "dense", init, compress)


# ---------------------------------------------------------------------------
# top-k with error feedback (the paper's operator)
# ---------------------------------------------------------------------------

def make_topk_ef(cfg: CompressorConfig, leaf_specs=None, axis_sizes=None,
                 stage_dims=None) -> CompressorDef:
    edtype = jnp.dtype(cfg.error_dtype)
    wdtype = jnp.dtype(cfg.wire_dtype)
    axis_sizes = axis_sizes or {}
    # stage_dims: {"/"-joined leaf path -> FULL leading-dim size} for leaves
    # that arrive stage-SLICED on dim 0 (payload-level stage gather). kb must
    # be computed as-if-full so every stage selects the same per-block k as
    # the flat run (k = ratio * full size can round differently on a slice).
    stage_dims = stage_dims or {}
    layout = cfg.resolved_layout()
    impl = cfg.resolved_impl()
    if layout == "per_shard" and impl not in ("kernel", "reference"):
        raise ValueError(
            f"per_shard layout supports topk_impl 'kernel' | 'reference', "
            f"got {cfg.topk_impl!r}"
        )

    def init(tree):
        return tree_zeros_like(tree, dtype=edtype)

    def _idx_dtype(bc: int):
        return index_dtype(cfg, bc)

    def _leaf_sharded(e, x, spec, path):
        """Blocked view of the leaf in its natural (possibly TP-sharded)
        layout; selection + EF residual are block-local. The fused kernel
        and the unfused reference run the same iterative masked-argmax, so
        their payload support and residuals are bit-identical under fp32
        error_dtype (the kernel always accumulates in fp32 — module
        docstring)."""
        ax, axsz = _sharded_axis_of(spec, x.shape, axis_sizes)
        blocked = topk_lib.blocked_view_shape(x.shape, ax, cfg.block_size, axsz)
        full0 = stage_dims.get(path)
        eff_shape = (full0,) + x.shape[1:] if full0 else x.shape
        kb = _blocked_kb(cfg, eff_shape, blocked, path)
        if impl == "kernel":
            from repro.kernels.topk_ef import ops as kops  # lazy: optional dep

            vals, idxs, new_e = kops.blocked_topk_ef(
                x.astype(edtype).reshape(blocked), e.reshape(blocked), kb
            )
            new_e = new_e.astype(edtype).reshape(e.shape)
        else:
            g = (x.astype(edtype) + e).reshape(blocked)
            p = topk_lib.blocked_topk(g, kb)
            vals, idxs = p.values, p.indices
            new_e = (g - topk_lib._scatter_last(
                vals.astype(edtype), idxs, blocked[-1]
            )).reshape(e.shape)
        payload = topk_lib.BlockPayload(
            vals.astype(wdtype), idxs.astype(_idx_dtype(blocked[-1])),
            blocked, x.shape,
        )
        return payload, new_e

    def _leaf_flat(e, x, path):
        k = cfg.leaf_k(x.size, path)
        if impl == "kernel":
            from repro.kernels.topk_ef import ops as kops  # lazy: optional dep

            p, new_e = kops.topk_ef(
                x.reshape(-1).astype(edtype), e.reshape(-1),
                jnp.asarray(1.0, edtype), k, cfg.block_size,
            )
            new_e = new_e.astype(edtype).reshape(e.shape)
        else:
            flat = x.reshape(-1).astype(edtype) + e.reshape(-1)
            p = _flat_topk(cfg, flat, k)
            new_e = (flat - p.densify()).reshape(e.shape)
        wire = p.values.astype(wdtype)
        return topk_lib.SparsePayload(wire, p.indices, p.size), new_e

    def compress(err, g, key):
        paths, leaves, treedef = tree_flatten_with_paths(g)
        err_leaves = jax.tree.leaves(err)
        if layout == "per_shard":
            specs = _spec_leaves(leaf_specs, leaves)
            pairs = [
                _leaf_sharded(e, x, s, p)
                for e, x, s, p in zip(err_leaves, leaves, specs, paths)
            ]
        else:
            pairs = [
                _leaf_flat(e, x, p)
                for e, x, p in zip(err_leaves, leaves, paths)
            ]
        payload = jax.tree.unflatten(treedef, [p for p, _ in pairs])
        new_err = jax.tree.unflatten(treedef, [e for _, e in pairs])
        return payload, new_err

    return CompressorDef("topk_ef", "sparse", layout, init, compress)


# ---------------------------------------------------------------------------
# random-k (unbiased, no EF needed)
# ---------------------------------------------------------------------------

def make_randk(cfg: CompressorConfig) -> CompressorDef:
    wdtype = jnp.dtype(cfg.wire_dtype)

    def init(tree):
        return ()

    def compress(state, g, key):
        assert key is not None, "randk requires a PRNG key"
        paths, leaves, treedef = tree_flatten_with_paths(g)
        keys = jax.random.split(key, len(leaves))

        def leaf(x, p, k):
            sp = topk_lib.random_k(
                x.reshape(-1).astype(jnp.float32), cfg.leaf_k(x.size, p), k
            )
            # values cross the wire at wire_dtype, like topk_ef — keeps the
            # payload consistent with the transport's bits_wire accounting
            return topk_lib.SparsePayload(
                sp.values.astype(wdtype), sp.indices, sp.size
            )

        payload = [leaf(x, p, k) for x, p, k in zip(leaves, paths, keys)]
        return jax.tree.unflatten(treedef, payload), state

    layout = "flat" if cfg.resolved_layout() == "flat" else "per_tensor"
    return CompressorDef("randk", "sparse", layout, init, compress)


# ---------------------------------------------------------------------------
# QSGD stochastic quantization (dense transport of dequantized values)
# ---------------------------------------------------------------------------

def make_qsgd(cfg: CompressorConfig) -> CompressorDef:
    s = cfg.qsgd_levels

    def init(tree):
        return ()

    def compress(state, g, key):
        assert key is not None, "qsgd requires a PRNG key"
        leaves, treedef = jax.tree.flatten(g)
        keys = jax.random.split(key, len(leaves))

        def leaf(x, k):
            x32 = x.astype(jnp.float32)
            nrm = jnp.linalg.norm(x32.reshape(-1)) + 1e-12
            level = jnp.abs(x32) / nrm * s
            low = jnp.floor(level)
            prob = level - low
            rnd = jax.random.uniform(k, x.shape)
            q = (low + (rnd < prob)) / s
            return (jnp.sign(x32) * nrm * q).astype(x.dtype)

        out = [leaf(x, k) for x, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out), state

    return CompressorDef("qsgd", "dense", "dense", init, compress)


# ---------------------------------------------------------------------------
# signSGD with error feedback (1 bit + per-leaf scale)
# ---------------------------------------------------------------------------

def make_signsgd_ef(cfg: CompressorConfig) -> CompressorDef:
    edtype = jnp.dtype(cfg.error_dtype)

    def init(tree):
        return tree_zeros_like(tree, dtype=edtype)

    def compress(err, g, key):
        def leaf(e, x):
            corr = x.astype(edtype) + e
            scale = jnp.mean(jnp.abs(corr))
            q = jnp.sign(corr) * scale
            return q.astype(x.dtype), corr - q

        g_leaves, treedef = jax.tree.flatten(g)
        pairs = [leaf(e, x) for e, x in zip(jax.tree.leaves(err), g_leaves)]
        payload = jax.tree.unflatten(treedef, [p for p, _ in pairs])
        new_err = jax.tree.unflatten(treedef, [e for _, e in pairs])
        return payload, new_err

    return CompressorDef("signsgd_ef", "dense", "dense", init, compress)


# ---------------------------------------------------------------------------
# TernGrad ternary stochastic quantization
# ---------------------------------------------------------------------------

def make_terngrad(cfg: CompressorConfig) -> CompressorDef:
    def init(tree):
        return ()

    def compress(state, g, key):
        assert key is not None, "terngrad requires a PRNG key"
        leaves, treedef = jax.tree.flatten(g)
        keys = jax.random.split(key, len(leaves))

        def leaf(x, k):
            x32 = x.astype(jnp.float32)
            s = jnp.max(jnp.abs(x32)) + 1e-12
            prob = jnp.abs(x32) / s
            rnd = jax.random.uniform(k, x.shape)
            t = jnp.sign(x32) * (rnd < prob)
            return (s * t).astype(x.dtype)

        out = [leaf(x, k) for x, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out), state

    return CompressorDef("terngrad", "dense", "dense", init, compress)


_REGISTRY = {
    "identity": make_identity,
    "topk_ef": make_topk_ef,
    "randk": make_randk,
    "qsgd": make_qsgd,
    "signsgd_ef": make_signsgd_ef,
    "terngrad": make_terngrad,
}


def build_compressor(cfg: CompressorConfig, leaf_specs=None, axis_sizes=None,
                     stage_dims=None) -> CompressorDef:
    if cfg.name not in _REGISTRY:
        raise ValueError(f"unknown compressor {cfg.name!r}; have {sorted(_REGISTRY)}")
    if cfg.name == "topk_ef":
        return make_topk_ef(cfg, leaf_specs=leaf_specs, axis_sizes=axis_sizes,
                            stage_dims=stage_dims)
    return _REGISTRY[cfg.name](cfg)

"""Gradient compressors: the paper's top-k + error feedback, and baselines.

A compressor is a pure-functional triple (init, compress, densify-semantics)
packaged as a ``CompressorDef``. Compression always receives the already
gamma-folded quantity ``g = lr * grad + error`` (paper eq. 8's g_m^t); error
feedback state is owned by the compressor and updated *candidately*: the
caller (sasg.py) commits or discards the candidate state depending on the
adaptive send/skip decision.

Kinds:
- ``sparse``: payload is a pytree of SparsePayload (fixed-k values+indices);
  exchanged with a worker-axis all-gather then local scatter-add (comm.py).
- ``dense``: payload is a dense tree (possibly quantize-dequantized values);
  exchanged with a plain psum. Bit accounting still reflects the encoded
  width (e.g. 1 bit/coord for signSGD), because on a real transport the
  encoded form is what crosses the wire.

Implemented:
  identity     — distributed SGD / LASG transport (32d bits per upload)
  topk_ef      — paper's T_k with error feedback (32k bits) [SASG/Sparse]
  randk        — unbiased random-k (Wangni et al., 2018)
  qsgd         — QSGD stochastic quantization (Alistarh et al., 2017)
  signsgd_ef   — 1-bit sign with error feedback (Karimireddy et al., 2019)
  terngrad     — ternary stochastic quantization (Wen et al., 2017)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import topk as topk_lib
from .types import (
    Tree,
    ceil_div,
    tree_flatten_concat,
    tree_size,
    tree_unflatten_concat,
    tree_zeros_like,
)


@dataclass(frozen=True)
class CompressorConfig:
    name: str = "topk_ef"
    k_ratio: float = 0.01          # paper uses top-1% (k = 0.01 d)
    # block granularity: the sharded impl selects kb=ceil(k_ratio*block) per
    # block via iterative argmax, so smaller blocks keep the iteration count
    # low (256 -> kb=3 at 1%); the flat impls use bigger blocks.
    block_size: int = 256
    # "sharded": shard-aligned blocked top-k on the leaf's natural layout —
    #            zero resharding, the production default (DESIGN.md §2).
    # "exact"/"block": flat-vector operators (paper-exact; small models).
    # "kernel": flat blocked top-k through the fused Pallas kernel.
    topk_impl: str = "sharded"
    bucket: str = "per_tensor"     # "per_tensor" | "global"
    wire_dtype: str = "float32"    # payload value dtype on the wire
    error_dtype: str = "float32"   # EF accumulator dtype
    # Beyond-paper (EXPERIMENTS.md §Perf iter 5): block-LOCAL indices fit in
    # u8/u16 for block_size <= 256/65536, shrinking payload wire bytes vs
    # the flat operator's mandatory 32-bit global indices.
    compact_indices: bool = False
    qsgd_levels: int = 256         # QSGD quantization levels (8-bit default)

    def leaf_k(self, size: int) -> int:
        return max(1, int(round(self.k_ratio * size)))


class CompressorDef(NamedTuple):
    name: str
    kind: str  # "sparse" | "dense"
    init: Callable[[Tree], Tree]
    # compress(state, g_tree, key) -> (payload_tree, candidate_state)
    compress: Callable[[Tree, Tree, Optional[jax.Array]], tuple[Any, Tree]]
    # static bit accounting per upload, from a template (abstract ok) tree
    bits_paper: Callable[[Tree], float]
    bits_wire: Callable[[Tree], float]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _leaf_topk(cfg: CompressorConfig, flat: jax.Array) -> topk_lib.SparsePayload:
    k = cfg.leaf_k(flat.size)
    if cfg.topk_impl == "exact":
        return topk_lib.exact_topk(flat, k)
    elif cfg.topk_impl == "block":
        return topk_lib.block_topk(flat, k, cfg.block_size)
    elif cfg.topk_impl == "kernel":
        from repro.kernels.topk_ef import ops as kops  # lazy: optional dep

        return kops.block_topk(flat, k, cfg.block_size)
    raise ValueError(f"unknown topk_impl {cfg.topk_impl!r}")


def _maybe_global(cfg: CompressorConfig, tree: Tree) -> Tree:
    """Collapse the tree into a single flat pseudo-leaf in global mode."""
    if cfg.bucket == "global":
        return {"__global__": tree_flatten_concat(tree)}
    return tree


def _unglobal(cfg: CompressorConfig, tree: Tree, like: Tree) -> Tree:
    if cfg.bucket == "global":
        return tree_unflatten_concat(tree["__global__"], like)
    return tree


def _total_k(cfg: CompressorConfig, template: Tree) -> int:
    if cfg.bucket == "global":
        d = tree_size(template)
        if cfg.topk_impl == "block":
            nb = ceil_div(d, cfg.block_size)
            return nb * max(1, ceil_div(cfg.leaf_k(d), nb))
        return cfg.leaf_k(d)
    total = 0
    for x in jax.tree.leaves(template):
        k = cfg.leaf_k(x.size)
        if cfg.topk_impl in ("block", "kernel"):
            nb = ceil_div(x.size, cfg.block_size)
            k = nb * min(max(1, ceil_div(k, nb)), cfg.block_size)
        total += min(k, x.size)
    return total


def _dtype_bits(name: str) -> int:
    return jnp.dtype(name).itemsize * 8


# ---------------------------------------------------------------------------
# identity (SGD / LASG transport)
# ---------------------------------------------------------------------------

def make_identity(cfg: CompressorConfig) -> CompressorDef:
    def init(tree):
        return ()

    def compress(state, g, key):
        return g, state

    def bits(template):
        return 32.0 * tree_size(template)

    return CompressorDef("identity", "dense", init, compress, bits, bits)


# ---------------------------------------------------------------------------
# top-k with error feedback (the paper's operator)
# ---------------------------------------------------------------------------

def _is_spec(s) -> bool:
    from jax.sharding import PartitionSpec

    return s is None or isinstance(s, PartitionSpec)


def _sharded_axis_of(spec, shape, axis_sizes) -> tuple:
    """(axis_index_or_None, axis_size) of the last mesh-sharded leaf dim."""
    if spec is None:
        return None, 1
    found, size = None, 1
    for i, entry in enumerate(tuple(spec)[: len(shape)]):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        s = 1
        for n in names:
            s *= axis_sizes.get(n, 1)
        if s > 1:
            found, size = i, s
    return found, size


def _blocked_kb(cfg: CompressorConfig, shape: tuple, blocked: tuple) -> int:
    size = 1
    for d in shape:
        size *= d
    k = cfg.leaf_k(size)
    nblocks = size // blocked[-1]
    return min(max(1, -(-k // nblocks)), blocked[-1])


def make_topk_ef(cfg: CompressorConfig, leaf_specs=None, axis_sizes=None) -> CompressorDef:
    edtype = jnp.dtype(cfg.error_dtype)
    axis_sizes = axis_sizes or {}
    sharded = cfg.topk_impl == "sharded" and cfg.bucket != "global"

    def init(tree):
        return tree_zeros_like(_maybe_global(cfg, tree), dtype=edtype)

    def _idx_dtype(bc: int):
        if not cfg.compact_indices:
            return jnp.int32
        if bc <= 256:
            return jnp.uint8
        if bc <= 65536:
            return jnp.uint16
        return jnp.int32

    def _leaf_sharded(e, x, spec):
        ax, axsz = _sharded_axis_of(spec, x.shape, axis_sizes)
        blocked = topk_lib.blocked_view_shape(x.shape, ax, cfg.block_size, axsz)
        kb = _blocked_kb(cfg, x.shape, blocked)
        g = (x.astype(edtype) + e).reshape(blocked)
        p = topk_lib.blocked_topk(g, kb)
        new_e = (g - topk_lib._scatter_last(
            p.values.astype(edtype), p.indices, blocked[-1]
        )).reshape(e.shape)
        p = topk_lib.BlockPayload(
            p.values.astype(jnp.dtype(cfg.wire_dtype)),
            p.indices.astype(_idx_dtype(blocked[-1])),
            blocked, x.shape,
        )
        return p, new_e

    def _leaf_flat(e, x):
        flat = x.reshape(-1).astype(edtype) + e.reshape(-1)
        p = _leaf_topk(cfg, flat)
        new_e = (flat - p.densify()).reshape(e.shape)
        wire = p.values.astype(jnp.dtype(cfg.wire_dtype))
        return topk_lib.SparsePayload(wire, p.indices, p.size), new_e

    def compress(err, g, key):
        g = _maybe_global(cfg, g)
        flat_leaves, treedef = jax.tree.flatten(g)
        err_leaves = jax.tree.leaves(err)
        if sharded:
            spec_leaves = (
                jax.tree.leaves(leaf_specs, is_leaf=_is_spec)
                if leaf_specs is not None else [None] * len(flat_leaves)
            )
            if len(spec_leaves) != len(flat_leaves):
                spec_leaves = [None] * len(flat_leaves)
            pairs = [
                _leaf_sharded(e, x, s)
                for e, x, s in zip(err_leaves, flat_leaves, spec_leaves)
            ]
        else:
            pairs = [leaf for leaf in map(_leaf_flat, err_leaves, flat_leaves)]
        payload = jax.tree.unflatten(treedef, [p for p, _ in pairs])
        new_err = jax.tree.unflatten(treedef, [e for _, e in pairs])
        return payload, new_err

    def _total_k_eff(template):
        if not sharded:
            return _total_k(cfg, template)
        total = 0
        spec_leaves = (
            jax.tree.leaves(leaf_specs, is_leaf=_is_spec)
            if leaf_specs is not None else None
        )
        leaves = jax.tree.leaves(template)
        if spec_leaves is None or len(spec_leaves) != len(leaves):
            spec_leaves = [None] * len(leaves)
        for x, s in zip(leaves, spec_leaves):
            ax, axsz = _sharded_axis_of(s, x.shape, axis_sizes)
            blocked = topk_lib.blocked_view_shape(x.shape, ax, cfg.block_size, axsz)
            kb = _blocked_kb(cfg, x.shape, blocked)
            total += (x.size // blocked[-1]) * kb
        return total

    def bits_paper(template):
        return 32.0 * _total_k_eff(template)

    def bits_wire(template):
        vb = _dtype_bits(cfg.wire_dtype)
        if not sharded:
            return float(vb + 32) * _total_k_eff(template)
        spec_leaves = (
            jax.tree.leaves(leaf_specs, is_leaf=_is_spec)
            if leaf_specs is not None else None
        )
        leaves = jax.tree.leaves(template)
        if spec_leaves is None or len(spec_leaves) != len(leaves):
            spec_leaves = [None] * len(leaves)
        total = 0.0
        for x, s in zip(leaves, spec_leaves):
            ax, axsz = _sharded_axis_of(s, x.shape, axis_sizes)
            blocked = topk_lib.blocked_view_shape(x.shape, ax, cfg.block_size, axsz)
            kb = _blocked_kb(cfg, x.shape, blocked)
            k_eff = (x.size // blocked[-1]) * kb
            ib = jnp.dtype(_idx_dtype(blocked[-1])).itemsize * 8
            total += float(vb + ib) * k_eff
        return total

    return CompressorDef("topk_ef", "sparse", init, compress, bits_paper, bits_wire)


# ---------------------------------------------------------------------------
# random-k (unbiased, no EF needed)
# ---------------------------------------------------------------------------

def make_randk(cfg: CompressorConfig) -> CompressorDef:
    def init(tree):
        return ()

    def compress(state, g, key):
        assert key is not None, "randk requires a PRNG key"
        g = _maybe_global(cfg, g)
        leaves, treedef = jax.tree.flatten(g)
        keys = jax.random.split(key, len(leaves))
        payload = [
            topk_lib.random_k(x.reshape(-1).astype(jnp.float32), cfg.leaf_k(x.size), k)
            for x, k in zip(leaves, keys)
        ]
        return jax.tree.unflatten(treedef, payload), state

    def bits_paper(template):
        if cfg.bucket == "global":
            return 32.0 * cfg.leaf_k(tree_size(template))
        return 32.0 * sum(cfg.leaf_k(x.size) for x in jax.tree.leaves(template))

    def bits_wire(template):
        return 2.0 * bits_paper(template)

    return CompressorDef("randk", "sparse", init, compress, bits_paper, bits_wire)


# ---------------------------------------------------------------------------
# QSGD stochastic quantization (dense transport of dequantized values)
# ---------------------------------------------------------------------------

def make_qsgd(cfg: CompressorConfig) -> CompressorDef:
    s = cfg.qsgd_levels

    def init(tree):
        return ()

    def compress(state, g, key):
        assert key is not None, "qsgd requires a PRNG key"
        leaves, treedef = jax.tree.flatten(g)
        keys = jax.random.split(key, len(leaves))

        def leaf(x, k):
            x32 = x.astype(jnp.float32)
            nrm = jnp.linalg.norm(x32.reshape(-1)) + 1e-12
            level = jnp.abs(x32) / nrm * s
            low = jnp.floor(level)
            prob = level - low
            rnd = jax.random.uniform(k, x.shape)
            q = (low + (rnd < prob)) / s
            return (jnp.sign(x32) * nrm * q).astype(x.dtype)

        out = [leaf(x, k) for x, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out), state

    def bits(template):
        d = tree_size(template)
        n_leaves = len(jax.tree.leaves(template))
        return (math.log2(s) + 1.0) * d + 32.0 * n_leaves

    return CompressorDef("qsgd", "dense", init, compress, bits, bits)


# ---------------------------------------------------------------------------
# signSGD with error feedback (1 bit + per-leaf scale)
# ---------------------------------------------------------------------------

def make_signsgd_ef(cfg: CompressorConfig) -> CompressorDef:
    edtype = jnp.dtype(cfg.error_dtype)

    def init(tree):
        return tree_zeros_like(tree, dtype=edtype)

    def compress(err, g, key):
        def leaf(e, x):
            corr = x.astype(edtype) + e
            scale = jnp.mean(jnp.abs(corr))
            q = jnp.sign(corr) * scale
            return q.astype(x.dtype), corr - q

        g_leaves, treedef = jax.tree.flatten(g)
        pairs = [leaf(e, x) for e, x in zip(jax.tree.leaves(err), g_leaves)]
        payload = jax.tree.unflatten(treedef, [p for p, _ in pairs])
        new_err = jax.tree.unflatten(treedef, [e for _, e in pairs])
        return payload, new_err

    def bits(template):
        d = tree_size(template)
        n_leaves = len(jax.tree.leaves(template))
        return 1.0 * d + 32.0 * n_leaves

    return CompressorDef("signsgd_ef", "dense", init, compress, bits, bits)


# ---------------------------------------------------------------------------
# TernGrad ternary stochastic quantization
# ---------------------------------------------------------------------------

def make_terngrad(cfg: CompressorConfig) -> CompressorDef:
    def init(tree):
        return ()

    def compress(state, g, key):
        assert key is not None, "terngrad requires a PRNG key"
        leaves, treedef = jax.tree.flatten(g)
        keys = jax.random.split(key, len(leaves))

        def leaf(x, k):
            x32 = x.astype(jnp.float32)
            s = jnp.max(jnp.abs(x32)) + 1e-12
            prob = jnp.abs(x32) / s
            rnd = jax.random.uniform(k, x.shape)
            t = jnp.sign(x32) * (rnd < prob)
            return (s * t).astype(x.dtype)

        out = [leaf(x, k) for x, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out), state

    def bits(template):
        d = tree_size(template)
        n_leaves = len(jax.tree.leaves(template))
        return math.log2(3.0) * d + 32.0 * n_leaves

    return CompressorDef("terngrad", "dense", init, compress, bits, bits)


_REGISTRY = {
    "identity": make_identity,
    "topk_ef": make_topk_ef,
    "randk": make_randk,
    "qsgd": make_qsgd,
    "signsgd_ef": make_signsgd_ef,
    "terngrad": make_terngrad,
}


def build_compressor(cfg: CompressorConfig, leaf_specs=None, axis_sizes=None) -> CompressorDef:
    if cfg.name not in _REGISTRY:
        raise ValueError(f"unknown compressor {cfg.name!r}; have {sorted(_REGISTRY)}")
    if cfg.name == "topk_ef":
        return make_topk_ef(cfg, leaf_specs=leaf_specs, axis_sizes=axis_sizes)
    return _REGISTRY[cfg.name](cfg)

"""SASG: the paper's algorithm as a composable gradient-exchange transform.

One engine expresses all four paper algorithms (Section 5.1) plus the extra
baselines, by composing two orthogonal switches:

                     selection OFF            selection ON
  identity           distributed SGD          LASG
  topk_ef            Sparse (top-k + EF)      SASG   <- the paper
  (randk/qsgd/...)   extra baselines          adaptive variants (beyond paper)

The exchange runs inside a partial-auto ``jax.shard_map``: worker axes
(pod/data) are manual, the model axis stays auto so TP sharding composes
transparently. Each worker:

  1. computes its fresh local gradient (and, if selection is on, the
     auxiliary gradient at its stale parameters **on the same minibatch** —
     the paper's variance-cancelling trick, eq. 6/7);
  2. decides send-vs-skip with the LASG rule (worker-local, zero comms);
  3. folds the learning rate and error feedback: g = lr * grad + e  (eq. 8);
  4. compresses (top-k -> fixed-k values+indices payload);
  5. contributes either the fresh payload or its cached stale payload to the
     worker-axis exchange (all-gather + local densify for sparse; psum for
     dense). Re-sending the cached payload is wire-identical to the paper's
     server-side reuse: the "server memory" is distributed across workers,
     and each worker's cache is exactly the sparse contribution the paper's
     server would have stored (DESIGN.md §2).

The returned ``update`` equals eq. (8)'s (1/M) [sum fresh T_k(g) + sum stale
T_k(g)] — identically replicated across workers, ready for `params - update`
(paper mode, fold_lr=True) or for a downstream optimizer (fold_lr=False,
beyond-paper composition e.g. with Adam, cf. CADA).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

# submodule imports (not the repro.comm package __init__) so that importing
# repro.comm first does not cycle through repro.core -> sasg -> repro.comm
from repro.comm.collectives import pmean_tree, psum_scalar
from repro.comm.transport import ActivationLayout, Transport, build_transport

from .compressors import CompressorConfig, CompressorDef
from .selection import (
    SelectionConfig,
    SelectionState,
    advance_tau,
    push_window,
    should_send,
)
from .types import Tree, tree_cast, tree_scale, tree_sq_norm, tree_where


@dataclass(frozen=True)
class SASGConfig:
    compressor: CompressorConfig = field(default_factory=CompressorConfig)
    selection: SelectionConfig = field(default_factory=SelectionConfig)
    mode: str = "flat"                    # "flat" | "hierarchical" (pod = worker)
    fold_lr: bool = True                  # paper folds gamma into the compressed g
    stale_params_dtype: str = "float32"   # bf16 = beyond-paper memory saving
    name: str = "sasg"
    # pipeline-parallel knobs (no effect without a stage axis):
    pipeline_engine: str = "1f1b"         # "1f1b" | "gpipe" (reference)
    act_layout: Optional[ActivationLayout] = None  # 1F1B ring wire format
    # overlap: dispatch per-bucket collectives as gradients complete and
    # commit EF state double-buffered AFTER the collectives
    # (Transport.exchange_overlapped) — bit-identical to the sync exchange
    overlap: bool = False


# -- presets: the paper's four algorithms -----------------------------------

def sgd_config(**kw) -> SASGConfig:
    return SASGConfig(
        compressor=CompressorConfig(name="identity"),
        selection=SelectionConfig(enabled=False),
        name="sgd", **kw,
    )


def sparse_config(k_ratio: float = 0.01, **kw) -> SASGConfig:
    return SASGConfig(
        compressor=CompressorConfig(name="topk_ef", k_ratio=k_ratio),
        selection=SelectionConfig(enabled=False),
        name="sparse", **kw,
    )


def lasg_config(max_delay: int = 10, **kw) -> SASGConfig:
    return SASGConfig(
        compressor=CompressorConfig(name="identity"),
        selection=SelectionConfig(enabled=True, max_delay=max_delay),
        name="lasg", **kw,
    )


def sasg_config(k_ratio: float = 0.01, max_delay: int = 10, **kw) -> SASGConfig:
    return SASGConfig(
        compressor=CompressorConfig(name="topk_ef", k_ratio=k_ratio),
        selection=SelectionConfig(enabled=True, max_delay=max_delay),
        name="sasg", **kw,
    )


PRESETS = {
    "sgd": sgd_config,
    "sparse": sparse_config,
    "lasg": lasg_config,
    "sasg": sasg_config,
}


class WorkerState(NamedTuple):
    """Per-worker (device-varying over worker axes) SASG state."""

    comp_state: Tree        # compressor state (EF error buffers)
    stale_cache: Tree       # last-sent payload (the distributed "server memory")
    stale_params: Tree      # w^{t - tau_m}; () when selection is off
    tau: jax.Array          # () int32


class GlobalState(NamedTuple):
    """Replicated SASG state."""

    window: jax.Array       # (D,) ||w^{t+1-d} - w^{t-d}||^2
    step: jax.Array         # () int32


class ExchangeInfo(NamedTuple):
    loss: jax.Array          # () f32   — this worker's fresh minibatch loss
    send: jax.Array          # () bool  — this worker uploaded
    num_sent: jax.Array      # () f32   — |M^t| across all workers
    rule_lhs: jax.Array      # selection diagnostics (0 when selection off)
    rule_rhs: jax.Array


class SASGExchange(NamedTuple):
    """Built exchange: functions to be called from the training step."""

    config: SASGConfig
    transport: Transport
    compressor: CompressorDef
    num_workers: int
    worker_axes: tuple
    reduce_axes: tuple
    init_worker: Callable[[Tree], WorkerState]
    init_global: Callable[[], GlobalState]
    # run(params, batch, wstate, gstate, lr, key, grad_fn) -> (update, wstate, info)
    run: Callable[..., tuple]
    bits_per_upload_paper: Callable[[Tree], float]
    bits_per_upload_wire: Callable[[Tree], float]


def build_exchange(
    cfg: SASGConfig,
    worker_axes: Sequence[str],
    reduce_axes: Sequence[str] = (),
    num_workers: int = 1,
    leaf_specs=None,
    axis_sizes=None,
    grad_combine=None,
    stage=None,
) -> SASGExchange:
    """Build the SASG exchange over a ``repro.comm`` Transport.

    ``grad_combine`` (optional) is the per-stage gradient combine under
    pipeline parallelism (``dist.pipeline.build_stage_combine``); the
    transport applies it so the exchange always sees the FULL gradient tree,
    and densifies against that tree — never against the (possibly
    stage-sliced) params tree.

    ``stage`` (optional, a ``comm.transport.StageInfo``, mutually exclusive
    with ``grad_combine``) selects the payload-level gather path instead:
    gradients stay stage-sliced, ``encode`` compresses the stage-LOCAL trunk
    slice, and only the k-sized payload is gathered over the stage axis
    (``Transport.gather_payload``); the selection rule runs on the
    transport's stage-psum'd ``diff_sq_norm``.
    """
    assert grad_combine is None or stage is None, (
        "grad_combine (dense fallback) and stage (payload gather) are "
        "mutually exclusive stage compositions"
    )
    transport = build_transport(
        cfg.compressor, worker_axes, num_workers,
        leaf_specs=leaf_specs, axis_sizes=axis_sizes, grad_combine=grad_combine,
        stage=stage, act_layout=cfg.act_layout,
    )
    compressor = transport.compressor
    sel = cfg.selection
    worker_axes = tuple(worker_axes)
    reduce_axes = tuple(reduce_axes)

    def init_worker(params: Tree) -> WorkerState:
        comp_state = transport.init_state(params)
        stale_cache = transport.zero_payload(params)
        if sel.enabled:
            stale_params = tree_cast(params, jnp.dtype(cfg.stale_params_dtype))
        else:
            stale_params = ()
        return WorkerState(comp_state, stale_cache, stale_params, jnp.ones((), jnp.int32))

    def init_global() -> GlobalState:
        return GlobalState(
            window=jnp.zeros((max(sel.max_delay, 1),), jnp.float32),
            step=jnp.zeros((), jnp.int32),
        )

    def _reduce(tree: Tree) -> Tree:
        # d-sized reduction -> owned by the repro.comm seam (audited there)
        return pmean_tree(tree, reduce_axes)

    def run(
        params: Tree,
        batch: Tree,
        wstate: WorkerState,
        gstate: GlobalState,
        lr: jax.Array,
        key: jax.Array,
        grad_fn: Callable[[Tree, Tree], tuple],
        force_skip: Optional[jax.Array] = None,
    ):
        """One SASG exchange. Called inside shard_map (manual worker axes).

        ``grad_fn(params, batch) -> (loss, grads)`` (i.e. value_and_grad).

        Under pipeline parallelism ``grad_fn`` returns per-stage gradient
        slices; ``transport.gather`` combines them into the full tree
        (identity otherwise)."""
        loss, g_fresh = grad_fn(params, batch)
        g_fresh = _reduce(transport.gather(g_fresh))
        if reduce_axes:
            loss = pmean_tree(loss, reduce_axes)

        if sel.enabled:
            stale_p = jax.tree.map(
                lambda s, p: s.astype(p.dtype), wstate.stale_params, params
            )
            if sel.probe_fraction < 1.0:
                # rule (6) on a probe sub-batch: both sides re-evaluated on
                # the same probe data (the variance-cancelling pairing is
                # preserved); costs 2*p extra grads instead of 1x.
                def probe(x):
                    n = max(1, int(round(sel.probe_fraction * x.shape[0])))
                    return x[:n]

                pbatch = jax.tree.map(probe, batch)
                g_rule_fresh = _reduce(transport.gather(grad_fn(params, pbatch)[1]))
                g_stale = _reduce(transport.gather(grad_fn(stale_p, pbatch)[1]))
            else:
                g_rule_fresh = g_fresh
                g_stale = _reduce(transport.gather(grad_fn(stale_p, batch)[1]))
            # alpha_d defaults to alpha_scale/lr (paper grid); lr is traced, so
            # compute rhs directly here.
            if sel.alphas is not None:
                a = jnp.asarray(sel.alphas, jnp.float32)
            else:
                a = sel.alpha_scale / jnp.maximum(lr, 1e-12)
                a = jnp.broadcast_to(a, (sel.max_delay,)).astype(jnp.float32)
            sstate = SelectionState(tau=wstate.tau, window=gstate.window)
            # payload-gather path: trunk grads are stage-local slices, so the
            # rule's ||.||^2 must psum the trunk part over the stage axis
            # (transport.diff_sq_norm) for all stages to agree on send/skip
            dsn = transport.diff_sq_norm if transport.stage is not None else None
            send = should_send(
                sel, g_rule_fresh, g_stale, sstate, a, num_workers, force_skip,
                diff_sq_norm=dsn,
            )
            if dsn is not None:
                lhs = dsn(g_rule_fresh, g_stale)
            else:
                lhs = tree_sq_norm(jax.tree.map(jnp.subtract, g_rule_fresh, g_stale))
            rhs = jnp.sum(a * gstate.window) / float(num_workers) ** 2
        else:
            send = jnp.ones((), bool)
            lhs = jnp.zeros(())
            rhs = jnp.zeros(())

        # Always upload on the very first step (empty caches).
        send = send | (gstate.step == 0)

        # Paper eq. (8): g_m^t = gamma * grad + e_m^t (error folded inside the
        # compressor; gamma folded here when fold_lr). The transport owns the
        # wire layout, the worker-axis collectives, and densification — the
        # densify template is the FULL gradient tree ``g``, never the params
        # tree (whose trunk is stage-sliced under pipelining).
        g = tree_scale(g_fresh, lr) if cfg.fold_lr else g_fresh
        payload_fresh, comp_state_cand = transport.encode(wstate.comp_state, g, key)
        # payload-gather path: the k-sized trunk payload slices all-gather
        # over the stage axis HERE (identity otherwise) — the stale cache
        # then stores the full gathered payload, so skip-step replays are
        # collective-free over stages just like in the flat run
        payload_fresh = transport.gather_payload(payload_fresh)

        if cfg.overlap:
            # per-bucket select -> dispatch as each gradient bucket is ready,
            # EF commit emitted AFTER the collectives (double-buffered
            # candidate/old state pair) — bit-identical per-leaf ops to the
            # sync path below. The traced ``send`` is passed even when the
            # rule is off (it is then the constant-True first-step mask) so
            # both paths emit the SAME where-gates: dropping them would
            # change the program around the step's psums and XLA's
            # all-reduce regrouping can shift their summation order by an
            # ulp (send=None remains a transport-level API for callers whose
            # sync path has no gates at all).
            update, payload, comp_state_new = transport.exchange_overlapped(
                payload_fresh, wstate.stale_cache, comp_state_cand,
                wstate.comp_state, send, g,
            )
        else:
            payload = tree_where(send, payload_fresh, wstate.stale_cache)
            comp_state_new = tree_where(send, comp_state_cand, wstate.comp_state)
            update = transport.densify(transport.exchange(payload), g)

        if sel.enabled:
            stale_params_new = tree_where(
                send,
                tree_cast(params, jnp.dtype(cfg.stale_params_dtype)),
                wstate.stale_params,
            )
        else:
            stale_params_new = ()

        new_wstate = WorkerState(
            comp_state=comp_state_new,
            stale_cache=payload,
            stale_params=stale_params_new,
            tau=advance_tau(SelectionState(wstate.tau, gstate.window), send),
        )
        # send is identical within a reduce group (g_fresh was pmean'd over
        # reduce_axes), so summing over worker axes alone counts |M^t|.
        num_sent = psum_scalar(send.astype(jnp.float32), worker_axes)
        info = ExchangeInfo(
            loss=loss, send=send, num_sent=num_sent, rule_lhs=lhs, rule_rhs=rhs
        )
        return update, new_wstate, info

    return SASGExchange(
        config=cfg,
        transport=transport,
        compressor=compressor,
        num_workers=num_workers,
        worker_axes=worker_axes,
        reduce_axes=reduce_axes,
        init_worker=init_worker,
        init_global=init_global,
        run=run,
        bits_per_upload_paper=transport.bits_paper,
        bits_per_upload_wire=transport.bits_wire,
    )


def update_global_state(
    gstate: GlobalState, applied_delta_sq_norm: jax.Array
) -> GlobalState:
    """Push ||w^{t+1} - w^t||^2 into the window and advance the step."""
    sstate = SelectionState(tau=jnp.zeros((), jnp.int32), window=gstate.window)
    return GlobalState(
        window=push_window(sstate, applied_delta_sq_norm),
        step=gstate.step + 1,
    )

"""SASG core: the paper's contribution as composable JAX transforms."""
from .compressors import CompressorConfig, CompressorDef, build_compressor
from .sasg import (
    GlobalState,
    PRESETS,
    SASGConfig,
    SASGExchange,
    WorkerState,
    build_exchange,
    lasg_config,
    sasg_config,
    sgd_config,
    sparse_config,
    update_global_state,
)
from .selection import SelectionConfig, SelectionState
from .topk import SparsePayload, block_topk, exact_topk, random_k
from .types import CommCounters

__all__ = [
    "CompressorConfig", "CompressorDef", "build_compressor",
    "GlobalState", "PRESETS", "SASGConfig", "SASGExchange", "WorkerState",
    "build_exchange", "lasg_config", "sasg_config", "sgd_config",
    "sparse_config", "update_global_state",
    "SelectionConfig", "SelectionState",
    "SparsePayload", "block_topk", "exact_topk", "random_k",
    "CommCounters",
]

"""Communication accounting — paper Table 1/2/3 semantics.

Two views are maintained and reported side by side (DESIGN.md §2):

- *algorithmic* (paper convention): rounds = uploads that actually carry
  fresh information (|M^t| per step); bits = 32 per transmitted element
  (k for sparse, d for dense). This is what Tables 1-2 count and what an
  async PS transport would pay.
- *wire* (TPU bulk-synchronous reality): sparse payloads also carry 32-bit
  indices; skipped workers still occupy their fixed-k all-gather slot. The
  dry-run/roofline reports physical collective bytes; this module reconciles
  the two.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from .types import CommCounters, Tree, tree_size


@dataclass(frozen=True)
class CommModel:
    """Static per-iteration cost model (paper Table 1)."""

    d: int          # model dimension
    k: int          # sparsification level
    M: int          # number of workers

    def bits_per_iter(self, method: str, num_sent: float | None = None) -> float:
        m = num_sent if num_sent is not None else self.M
        return {
            "sgd": 32.0 * self.d * self.M,
            "sparse": 32.0 * self.k * self.M,
            "lasg": 32.0 * self.d * m,
            "sasg": 32.0 * self.k * m,
        }[method]

    def total_bits(self, method: str, T: int, sum_rounds: float | None = None) -> float:
        if method in ("sgd", "sparse"):
            return self.bits_per_iter(method) * T
        assert sum_rounds is not None, "adaptive methods need the realized sum |M^t|"
        per_upload = 32.0 * (self.k if method == "sasg" else self.d)
        return per_upload * sum_rounds


def accumulate(
    counters: CommCounters,
    num_sent: jax.Array,
    bits_paper_per_upload: float,
    bits_wire_per_upload: float,
) -> CommCounters:
    """Fold one step's uploads into the running counters (jit-safe)."""
    return CommCounters(
        rounds=counters.rounds + num_sent,
        bits_paper=counters.bits_paper + num_sent * bits_paper_per_upload,
        bits_wire=counters.bits_wire + num_sent * bits_wire_per_upload,
    )


@dataclass(frozen=True)
class PipelineCommModel:
    """Static per-step pipeline (stage-axis) traffic accounting.

    Orthogonal to the SASG upload counters above: the activation ring runs
    every step, regardless of the send/skip decisions. Two engines
    (``dist/pipeline.py``):

    - ``"gpipe"``: one dense fp32 microbatch activation per stage per tick
      over ``n_micro + stages - 1`` ticks, plus the final output-replicating
      psum (``n_micro`` activation hops per stage).
    - ``"1f1b"`` (the default): forward carries AND backward cotangent
      carries, ``n_micro + stages - 2`` hops each per stage, all in the
      ``ActivationLayout`` wire format (``hop_payload_bits`` — the dense
      wire-dtype cast or the blocked top-k payload,
      ``bits.activation_payload_bits``); the finished-output broadcast is a
      stage-axis all-reduce of the encoded ``n_micro``-activation block, so
      each stage pays the ring all-reduce factor ``2(S-1)/S`` of
      ``bcast_payload_bits``.

    ``gather_bits`` additionally accounts the stage-axis GRADIENT-exchange
    traffic per step — the k-sized payload all-gather on the payload-gather
    hot path (plus the tiny prepare-grad psum), or the d-sized dense stage
    combine on the fallback path. Surfaced by the train step as
    ``pipe_ring_bits_step`` / ``pipe_gather_bits_step`` (and their sum
    ``pipe_bits_step``) and by ``benchmarks/run.py --stages``; the HLO audit
    gates the compiled ring wire bytes against this model.
    """

    stages: int
    n_micro: int
    act_elems: int              # elements in ONE microbatch activation
    bits_per_elem: int = 32     # dense ring payload width (GPipe engine)
    gather_bits: float = 0.0    # stage-axis gradient-exchange bits per step
    engine: str = "gpipe"       # "gpipe" | "1f1b"
    hop_payload_bits: float | None = None    # encoded per-hop bits (1f1b);
    #                                          None -> dense act_elems * bpe
    bcast_payload_bits: float | None = None  # encoded output-broadcast bits

    @property
    def ticks(self) -> int:
        if self.engine == "1f1b":
            return self.n_micro + 2 * (self.stages - 1)
        return self.n_micro + self.stages - 1

    def _dense_act_bits(self) -> float:
        return float(self.act_elems) * self.bits_per_elem

    def _hop_bits(self) -> float:
        if self.hop_payload_bits is not None:
            return float(self.hop_payload_bits)
        return self._dense_act_bits()

    def bits_per_stage_per_step(self) -> float:
        """ppermute traffic one stage emits per training step."""
        if self.engine == "1f1b":
            shifts = 2 * max(self.n_micro + self.stages - 2, 0)
            return shifts * self._hop_bits()
        return float(self.ticks) * self._dense_act_bits()

    def ring_bits_per_step(self) -> float:
        """Activation-ring traffic per step, summed over stages: the
        per-tick carries plus the finished-output broadcast."""
        if self.engine == "1f1b":
            bcast = (
                float(self.bcast_payload_bits)
                if self.bcast_payload_bits is not None
                else self.n_micro * self._dense_act_bits()
            )
            ar = 2.0 * (self.stages - 1) / max(self.stages, 1)
            return self.stages * (self.bits_per_stage_per_step() + ar * bcast)
        return self.stages * (
            self.bits_per_stage_per_step()
            + self.n_micro * self._dense_act_bits()
        )

    def bits_per_step(self) -> float:
        """Total stage-axis traffic per step: activation ring + gradient
        exchange (payload gather or dense combine)."""
        return self.ring_bits_per_step() + self.gather_bits


@dataclass(frozen=True)
class LinkModel:
    """Analytic transport-time model (paper Table 3 / Fig 5-6 setting).

    The paper measures GLOO point-to-point uploads at 1 Gbps per worker, with
    the server receiving sequentially. ``sequential_uplink=True`` reproduces
    that accounting; False models a fully parallel fabric (TPU ICI/DCI).
    """

    bandwidth_bps: float = 1e9
    latency_s: float = 1e-4
    sequential_uplink: bool = True

    def upload_time(self, bits_per_upload: float, num_uploads: float) -> float:
        per = bits_per_upload / self.bandwidth_bps + self.latency_s
        if self.sequential_uplink:
            return per * num_uploads
        return per


def model_dimension(params: Tree) -> int:
    return tree_size(params)

"""Communication accounting — paper Table 1/2/3 semantics.

Two views are maintained and reported side by side (DESIGN.md §2):

- *algorithmic* (paper convention): rounds = uploads that actually carry
  fresh information (|M^t| per step); bits = 32 per transmitted element
  (k for sparse, d for dense). This is what Tables 1-2 count and what an
  async PS transport would pay.
- *wire* (TPU bulk-synchronous reality): sparse payloads also carry 32-bit
  indices; skipped workers still occupy their fixed-k all-gather slot. The
  dry-run/roofline reports physical collective bytes; this module reconciles
  the two.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from .types import CommCounters, Tree, tree_size


@dataclass(frozen=True)
class CommModel:
    """Static per-iteration cost model (paper Table 1)."""

    d: int          # model dimension
    k: int          # sparsification level
    M: int          # number of workers

    def bits_per_iter(self, method: str, num_sent: float | None = None) -> float:
        m = num_sent if num_sent is not None else self.M
        return {
            "sgd": 32.0 * self.d * self.M,
            "sparse": 32.0 * self.k * self.M,
            "lasg": 32.0 * self.d * m,
            "sasg": 32.0 * self.k * m,
        }[method]

    def total_bits(self, method: str, T: int, sum_rounds: float | None = None) -> float:
        if method in ("sgd", "sparse"):
            return self.bits_per_iter(method) * T
        assert sum_rounds is not None, "adaptive methods need the realized sum |M^t|"
        per_upload = 32.0 * (self.k if method == "sasg" else self.d)
        return per_upload * sum_rounds


def accumulate(
    counters: CommCounters,
    num_sent: jax.Array,
    bits_paper_per_upload: float,
    bits_wire_per_upload: float,
) -> CommCounters:
    """Fold one step's uploads into the running counters (jit-safe)."""
    return CommCounters(
        rounds=counters.rounds + num_sent,
        bits_paper=counters.bits_paper + num_sent * bits_paper_per_upload,
        bits_wire=counters.bits_wire + num_sent * bits_wire_per_upload,
    )


@dataclass(frozen=True)
class PipelineCommModel:
    """Static per-step pipeline (stage-axis) traffic accounting.

    Orthogonal to the SASG upload counters above: the GPipe ring moves one
    microbatch activation per stage per tick over ``n_micro + stages - 1``
    ticks (dist/pipeline.py), every step, regardless of the send/skip
    decisions. ``gather_bits`` additionally accounts the stage-axis
    GRADIENT-exchange traffic per step — the k-sized payload all-gather on
    the payload-gather hot path (plus the tiny prepare-grad psum), or the
    d-sized dense stage combine on the fallback path. Surfaced by the train
    step as ``pipe_ring_bits_step`` / ``pipe_gather_bits_step`` (and their
    sum ``pipe_bits_step``) and by ``benchmarks/run.py --stages``.
    """

    stages: int
    n_micro: int
    act_elems: int              # elements in ONE microbatch activation
    bits_per_elem: int = 32     # ring payload width (16 for bf16 compute)
    gather_bits: float = 0.0    # stage-axis gradient-exchange bits per step

    @property
    def ticks(self) -> int:
        return self.n_micro + self.stages - 1

    def bits_per_stage_per_step(self) -> float:
        """ppermute traffic one stage emits per training step."""
        return float(self.ticks) * self.act_elems * self.bits_per_elem

    def ring_bits_per_step(self) -> float:
        """Activation-ring traffic per step: every stage's per-tick ppermute
        sends, plus the final psum that replicates the ``n_micro`` finished
        microbatch outputs to each stage (n_micro activation hops per
        stage)."""
        return self.stages * (
            self.bits_per_stage_per_step()
            + self.n_micro * self.act_elems * self.bits_per_elem
        )

    def bits_per_step(self) -> float:
        """Total stage-axis traffic per step: activation ring + gradient
        exchange (payload gather or dense combine)."""
        return self.ring_bits_per_step() + self.gather_bits


@dataclass(frozen=True)
class LinkModel:
    """Analytic transport-time model (paper Table 3 / Fig 5-6 setting).

    The paper measures GLOO point-to-point uploads at 1 Gbps per worker, with
    the server receiving sequentially. ``sequential_uplink=True`` reproduces
    that accounting; False models a fully parallel fabric (TPU ICI/DCI).
    """

    bandwidth_bps: float = 1e9
    latency_s: float = 1e-4
    sequential_uplink: bool = True

    def upload_time(self, bits_per_upload: float, num_uploads: float) -> float:
        per = bits_per_upload / self.bandwidth_bps + self.latency_s
        if self.sequential_uplink:
            return per * num_uploads
        return per


def model_dimension(params: Tree) -> int:
    return tree_size(params)

"""Shared pytree / numeric utilities for the SASG core.

Everything here is jit-safe, shape-static, and free of device-state side
effects. Trees are arbitrary pytrees of jnp arrays (model gradients,
parameters, error buffers, ...).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Tree = Any  # pytree of arrays


def tree_map(f: Callable, *trees: Tree) -> Tree:
    return jax.tree.map(f, *trees)


def tree_add(a: Tree, b: Tree) -> Tree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Tree, b: Tree) -> Tree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: Tree, s) -> Tree:
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a: Tree, dtype=None) -> Tree:
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), a)


def tree_where(pred, a: Tree, b: Tree) -> Tree:
    """Select between two trees on a scalar boolean predicate."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y.astype(x.dtype)), a, b)


def tree_sq_norm(a: Tree) -> jax.Array:
    """Global squared l2 norm of a tree, accumulated in fp32."""
    leaves = jax.tree.leaves(a)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def tree_size(a: Tree) -> int:
    """Total (static) element count of a tree."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a: Tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a: Tree, dtype) -> Tree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_flatten_concat(a: Tree, dtype=jnp.float32) -> jax.Array:
    """Concatenate every leaf into one flat vector (paper's global view)."""
    leaves = jax.tree.leaves(a)
    return jnp.concatenate([x.reshape(-1).astype(dtype) for x in leaves])


def tree_unflatten_concat(flat: jax.Array, like: Tree) -> Tree:
    """Inverse of tree_flatten_concat against a reference tree."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for x in leaves:
        out.append(flat[off : off + x.size].reshape(x.shape).astype(x.dtype))
        off += x.size
    return jax.tree.unflatten(treedef, out)


class CommCounters(NamedTuple):
    """Algorithmic communication accounting (paper Tables 1-2 semantics).

    All entries are scalar jnp values carried through the training state.
    ``rounds`` counts uploads (one upload == one worker-to-server round);
    ``bits_paper`` uses the paper's 32-bits-per-transmitted-element
    convention; ``bits_wire`` additionally charges index bits for sparse
    payloads (what a real transport would pay).
    """

    rounds: jax.Array
    bits_paper: jax.Array
    bits_wire: jax.Array

    @staticmethod
    def zeros() -> "CommCounters":
        z = jnp.zeros((), jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
        return CommCounters(rounds=z, bits_paper=z, bits_wire=z)

    def __add__(self, other: "CommCounters") -> "CommCounters":  # type: ignore[override]
        return CommCounters(
            self.rounds + other.rounds,
            self.bits_paper + other.bits_paper,
            self.bits_wire + other.bits_wire,
        )


def add_worker_axis(tree: Tree) -> Tree:
    """Add a leading singleton axis to every leaf (shard_map out_specs with a
    worker axis require rank >= 1 so per-worker outputs can concatenate)."""
    return jax.tree.map(lambda x: jnp.asarray(x)[None], tree)


def strip_worker_axis(tree: Tree) -> Tree:
    """Inverse of add_worker_axis, applied to the local shard inside
    shard_map (each worker sees a leading dim of 1)."""
    return jax.tree.map(lambda x: x[0], tree)


def path_str(path) -> str:
    """Render a jax tree path as the "/"-joined key string used for leaf
    bucket names and ``CompressorConfig.k_ratio_per_layer`` matching."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def tree_flatten_with_paths(tree: Tree, is_leaf=None):
    """(paths, leaves, treedef) with paths rendered via ``path_str``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    return [path_str(p) for p, _ in flat], [x for _, x in flat], treedef


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` so its size is a multiple of ``multiple``."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)

"""Deprecation shim — the collective transport moved to ``repro.comm``.

The worker-axis collectives live in :mod:`repro.comm.collectives`; payload
layout, densification, stage composition, and bit accounting live behind the
``Transport`` interface (:mod:`repro.comm.transport`). This module keeps the
old ``repro.core.comm`` import path working.
"""
from repro.comm.collectives import (  # noqa: F401
    AxisNames,
    dense_mean,
    exchange,
    reshape_like,
    sparse_allgather_mean,
)

__all__ = [
    "AxisNames", "dense_mean", "exchange", "reshape_like",
    "sparse_allgather_mean",
]

"""Top-k sparsification operators (paper Definition 1).

Three granularities, all of which are delta-approximate compressors in the
sense of Lemma 1 (with delta = k/d for the exact operator and
delta = k_block/block for the block variant, both >= k/d overall):

- ``exact_topk``:   exact global top-k over a flat vector (the paper's T_k).
- ``block_topk``:   split the flat vector into fixed-size blocks and keep the
                    top k_b of each block. TPU-native: each block's selection
                    is a local ``lax.top_k`` over the last axis, so a
                    model-axis-sharded leading dim stays fully local (no
                    cross-shard gather). This is the semantic implemented by
                    the Pallas kernel in ``repro.kernels.block_topk``.
- per-tensor:       driven by the caller (each pytree leaf compressed
                    independently); see ``compressors.py``.

All operators return fixed-shape ``(values, indices)`` payloads — XLA needs
static shapes, and fixed-k payloads are exactly what makes the sparse
all-gather exchange shape-static (DESIGN.md §2).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .types import ceil_div, pad_to_multiple


@jax.tree_util.register_pytree_node_class
class SparsePayload:
    """Fixed-size sparse representation of a flat vector.

    values:  (k,) float    selected coordinates (zero for padding slots)
    indices: (k,) int32    flat positions of the selected coordinates
    size:    static int    logical dense length d (aux data, never traced)
    """

    __slots__ = ("values", "indices", "size")

    def __init__(self, values, indices, size: int):
        self.values = values
        self.indices = indices
        self.size = size

    def tree_flatten(self):
        return (self.values, self.indices), self.size

    @classmethod
    def tree_unflatten(cls, size, children):
        return cls(children[0], children[1], size)

    def densify(self) -> jax.Array:
        """Scatter the payload back to a dense flat vector."""
        out = jnp.zeros((self.size,), self.values.dtype)
        return out.at[self.indices].add(self.values, mode="drop")

    def __repr__(self):
        return f"SparsePayload(k={getattr(self.values, 'shape', '?')}, d={self.size})"


def exact_topk(x: jax.Array, k: int) -> SparsePayload:
    """Exact global top-k by absolute value over a flat vector."""
    assert x.ndim == 1, "exact_topk expects a flat vector"
    k = int(min(k, x.size))
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    vals = x[idx]
    return SparsePayload(values=vals, indices=idx.astype(jnp.int32), size=x.size)


def block_topk(x: jax.Array, k: int, block_size: int = 2048) -> SparsePayload:
    """Block-local top-k: keep ceil(k/nblocks) per block of ``block_size``.

    The realized k may slightly exceed the requested k (per-block rounding);
    the payload is still fixed-shape. Padding tail positions are masked to
    -inf magnitude so they are never selected unless a block is all padding,
    in which case the selected value is exactly 0 and densify is a no-op.
    """
    assert x.ndim == 1
    d = x.size
    xb = pad_to_multiple(x, block_size)
    nb = xb.size // block_size
    xb = xb.reshape(nb, block_size)
    kb = max(1, ceil_div(int(min(k, d)), nb))
    kb = min(kb, block_size)
    mag = jnp.abs(xb)
    # Mask padding tail of the last block so indices stay in-range.
    pos = jnp.arange(nb * block_size).reshape(nb, block_size)
    mag = jnp.where(pos < d, mag, -jnp.inf)
    _, idx = jax.lax.top_k(mag, kb)  # (nb, kb) local indices
    vals = jnp.take_along_axis(xb, idx, axis=1)
    flat_idx = idx + (jnp.arange(nb) * block_size)[:, None]
    # Out-of-range (padding) slots: zero value, clamp index (drop-safe anyway).
    in_range = flat_idx < d
    vals = jnp.where(in_range, vals, 0.0)
    flat_idx = jnp.where(in_range, flat_idx, d - 1)
    return SparsePayload(
        values=vals.reshape(-1),
        indices=flat_idx.reshape(-1).astype(jnp.int32),
        size=d,
    )


def random_k(x: jax.Array, k: int, key: jax.Array) -> SparsePayload:
    """Unbiased random-k sparsification: E[payload.densify()] == x.

    Selected coordinates are scaled by d/k so the estimate is unbiased
    (Wangni et al., 2018).
    """
    assert x.ndim == 1
    d = x.size
    k = int(min(k, d))
    idx = jax.random.choice(key, d, shape=(k,), replace=False)
    vals = x[idx] * (d / k)
    return SparsePayload(values=vals, indices=idx.astype(jnp.int32), size=d)


def payload_k(p: SparsePayload) -> int:
    return int(p.values.shape[-1]) if p.values.ndim == 1 else int(p.values.size)


# ---------------------------------------------------------------------------
# shard-aligned block top-k (the production operator)
# ---------------------------------------------------------------------------
#
# Flattening a TP-sharded gradient leaf to 1-D erases its sharding: XLA then
# materializes the full leaf (fp32!) on every device, and the densify scatter
# runs over the unsharded flat vector (measured: ~30 GB/device of compression
# temps on llama3-8b train_4k — EXPERIMENTS.md §Perf iteration 1). Instead we
# block the leaf IN ITS NATURAL LAYOUT, with block boundaries aligned to the
# sharded axis, so top-k / EF residual / densify are all shard-local and only
# the (values, local-indices) payloads ever cross the worker axis.


@jax.tree_util.register_pytree_node_class
class BlockPayload:
    """Sparse payload over a blocked view of a (possibly sharded) leaf.

    values / indices: (*lead, nbc, kb) — kb selected per (lead, block);
    indices are LOCAL positions within the block (int32 < block_c).
    aux: (blocked_shape, orig_shape) — blocked = (*lead, nbc, block_c).
    """

    __slots__ = ("values", "indices", "blocked_shape", "orig_shape")

    def __init__(self, values, indices, blocked_shape, orig_shape):
        self.values = values
        self.indices = indices
        self.blocked_shape = tuple(blocked_shape)
        self.orig_shape = tuple(orig_shape)

    def tree_flatten(self):
        return (self.values, self.indices), (self.blocked_shape, self.orig_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    def densify(self) -> jax.Array:
        """Scatter back to the original leaf shape (shard-local scatter
        along the last/block axis; all leading dims are batch dims)."""
        dense = _scatter_last(self.values, self.indices, self.blocked_shape[-1])
        return dense.reshape(self.orig_shape)

    def __repr__(self):
        return (f"BlockPayload(blocked={self.blocked_shape}, "
                f"kb={self.values.shape[-1]})")


def _scatter_last(vals: jax.Array, idx: jax.Array, block_c: int) -> jax.Array:
    """Batched scatter-add along the last axis: (*B, kb) -> (*B, block_c)."""

    def row(v, i):
        return jnp.zeros((block_c,), v.dtype).at[i].add(v, mode="drop")

    fn = row
    for _ in range(vals.ndim - 1):
        fn = jax.vmap(fn)
    return fn(vals, idx)


def _largest_divisor_leq(n: int, cap: int) -> int:
    cap = min(cap, n)
    for b in range(cap, 0, -1):
        if n % b == 0:
            return b
    return 1


def blocked_view_shape(shape: tuple, sharded_axis: int | None,
                       target_block: int, axis_size: int = 1) -> tuple:
    """Choose the blocked view (*lead, nbc, block_c) for a leaf.

    - sharded axis is LAST: subdivide it so nbc is a multiple of the axis
      size (blocks never straddle shard boundaries).
    - sharded axis is interior (or None): merge all trailing unsharded dims
      into C and block that; the sharded axis stays a leading batch dim.
    """
    nd = len(shape)
    if sharded_axis is not None and sharded_axis == nd - 1:
        c_local = shape[-1] // max(axis_size, 1)
        bc = _largest_divisor_leq(c_local, target_block)
        nbc = shape[-1] // bc
        return shape[:-1] + (nbc, bc)
    cut = (sharded_axis + 1) if sharded_axis is not None else max(nd - 1, 1)
    if cut >= nd:  # sharded axis is last but handled above; safeguard
        cut = nd - 1
    c = 1
    for d in shape[cut:]:
        c *= d
    bc = _largest_divisor_leq(c, target_block)
    nbc = c // bc
    return shape[:cut] + (nbc, bc)


def blocked_topk(x_blocked: jax.Array, kb: int) -> "BlockPayload":
    """Top-kb by |x| within each block (last axis) via iterative masked
    argmax. Deliberately NOT lax.top_k: XLA's sort partitioner all-gathers
    sharded operands even when the sort dim is local (measured — see
    EXPERIMENTS.md §Perf iteration 2), whereas max/where/iota reductions
    partition cleanly. This is also bit-for-bit the algorithm of the fused
    Pallas kernel (repro.kernels.topk_ef), which executes the whole loop in
    one VMEM-resident HBM pass on real TPU hardware."""
    x32 = x_blocked.astype(jnp.float32)
    mag = jnp.abs(x32)
    bc = x_blocked.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, x_blocked.shape, x_blocked.ndim - 1)

    def body(_, carry):
        mag_c, vals, idxs, j = carry
        mx = jnp.max(mag_c, axis=-1, keepdims=True)
        first = jnp.min(
            jnp.where(mag_c == mx, col, bc), axis=-1, keepdims=True
        )
        sel = col == first
        v = jnp.sum(jnp.where(sel, x32, 0.0), axis=-1)
        vals = jax.lax.dynamic_update_index_in_dim(vals, v, j, vals.ndim - 1)
        idxs = jax.lax.dynamic_update_index_in_dim(
            idxs, first[..., 0], j, idxs.ndim - 1
        )
        return jnp.where(sel, -jnp.inf, mag_c), vals, idxs, j + 1

    vals0 = jnp.zeros(x_blocked.shape[:-1] + (kb,), jnp.float32)
    idxs0 = jnp.zeros(x_blocked.shape[:-1] + (kb,), jnp.int32)
    _, vals, idxs, _ = jax.lax.fori_loop(
        0, kb, body, (mag, vals0, idxs0, jnp.zeros((), jnp.int32))
    )
    return BlockPayload(
        values=vals, indices=idxs,
        blocked_shape=x_blocked.shape,
        orig_shape=x_blocked.shape,  # caller overwrites with the leaf shape
    )

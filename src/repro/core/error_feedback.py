"""Standalone error-feedback (memory) transform.

The paper's EF is built into ``compressors.make_topk_ef`` (the compressor owns
its residual so the send/skip branch can commit or discard it atomically).
This module additionally exposes EF as a generic wrapper usable around *any*
compression function — the classic Stich et al. (2018) / Karimireddy et al.
(2019) formulation — for composition experiments and property tests:

    e_{t+1} = (g_t + e_t) - C(g_t + e_t)

Invariant (tested with hypothesis): compressed + residual == corrected input,
exactly, for any deterministic C that returns a subset/projection of its
input.

Stage-sharded EF (pipeline parallelism, payload-gather hot path): the
residual buffers of trunk leaves are sharded over the stage axis exactly
like the params (``dist.sharding.ef_specs``) — each stage owns the
residuals of its own trunk slice, d/S memory per device. The residual a
stage holds depends only on the trunk COORDINATES it owns, never on the
stage count, because the stage-local encode uses the same blocked geometry
as the flat run (support-exactness, ``comm.transport``). Checkpoints store
the FULL logical array, so restoring onto a different stage count is pure
resharding: ``remap_error_state``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .types import Tree, tree_zeros_like


class EFState(NamedTuple):
    error: Tree


def ef_init(template: Tree, dtype=jnp.float32) -> EFState:
    return EFState(error=tree_zeros_like(template, dtype=dtype))


def ef_apply(
    state: EFState,
    g: Tree,
    compress_fn: Callable[[jax.Array], jax.Array],
) -> tuple[Tree, EFState]:
    """Apply C to the error-corrected gradient; return (compressed, state').

    ``compress_fn`` maps a flat fp32 vector to its compressed *dense*
    representation (e.g. densified top-k). Residual accumulates in fp32.
    """

    def leaf(e, x):
        corrected = x.astype(e.dtype).reshape(-1) + e.reshape(-1)
        out = compress_fn(corrected)
        new_e = (corrected - out).reshape(e.shape)
        return out.reshape(x.shape).astype(x.dtype), new_e

    g_leaves, treedef = jax.tree.flatten(g)
    pairs = [leaf(e, x) for e, x in zip(jax.tree.leaves(state.error), g_leaves)]
    compressed = jax.tree.unflatten(treedef, [c for c, _ in pairs])
    new_state = EFState(error=jax.tree.unflatten(treedef, [e for _, e in pairs]))
    return compressed, new_state


def remap_error_state(comp_state: Tree, shardings: Tree, mesh=None) -> Tree:
    """Reshard a restored compressor/EF state onto a new stage topology.

    Stage-sharded EF buffers checkpoint as FULL logical arrays (module
    docstring), so an elastic restart — save under S stages, resume under
    S' — never moves a residual to a different trunk coordinate: this is
    ``device_put`` onto the target shardings (``dist.sharding.ef_specs`` of
    the NEW mesh/strategy), bit-identical values, only the device placement
    of each trunk row changes. Works for the dense-combine fallback too,
    where the specs are stage-stripped and the "remap" is a plain
    replicated placement.

    ``shardings`` leaves may be ``jax.sharding.Sharding`` objects, or raw
    ``PartitionSpec``s when ``mesh`` is given (the checkpoint records specs,
    not device lists). Spec axis names that the TARGET mesh does not carry —
    the stage axis after an elastic restart with pipelining switched off, or
    any axis the new mesh holds at size 1 (meshes drop size-1 axes when the
    topology shrinks) — are stripped before binding: sharding a dim over a
    missing/trivial axis IS replication over it, so the strip is
    bit-preserving by construction, and without it ``NamedSharding``
    construction rejects the stale ``"stage"`` entry outright.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    def norm_axes(entry, live):
        # one PartitionSpec entry: name, tuple of names, or None
        if entry is None:
            return None
        names = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(n for n in names if n in live)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    def resolve(s):
        if not isinstance(s, PartitionSpec):
            return s
        if mesh is None:
            raise ValueError(
                "remap_error_state got a raw PartitionSpec leaf; pass the "
                "target mesh to bind it (or pass Sharding leaves)"
            )
        live = {
            n for n, sz in zip(mesh.axis_names, mesh.devices.shape) if sz > 1
        }
        return NamedSharding(mesh, PartitionSpec(*(norm_axes(e, live) for e in s)))

    return jax.tree.map(
        lambda x, s: jax.device_put(x, resolve(s)), comp_state, shardings
    )


def worker_dims_match(wstate: Tree, num_workers: int) -> bool:
    """True iff every worker-stacked leaf has leading dim ``num_workers``.

    The elastic membership layer (``train.elastic``) uses this to decide
    between the bit-exact carry (same worker set -> ``remap_error_state`` is
    pure data movement) and the DESIGN.md §5 cold start (worker set changed
    -> per-worker EF/stale state must be re-initialized; a stale residual
    belongs to a worker that no longer exists)."""
    leaves = jax.tree.leaves(wstate)
    if not leaves:
        return True  # plain strategy: no worker state, nothing to mismatch
    return all(
        jnp.ndim(x) >= 1 and x.shape[0] == num_workers for x in leaves
    )

"""Standalone error-feedback (memory) transform.

The paper's EF is built into ``compressors.make_topk_ef`` (the compressor owns
its residual so the send/skip branch can commit or discard it atomically).
This module additionally exposes EF as a generic wrapper usable around *any*
compression function — the classic Stich et al. (2018) / Karimireddy et al.
(2019) formulation — for composition experiments and property tests:

    e_{t+1} = (g_t + e_t) - C(g_t + e_t)

Invariant (tested with hypothesis): compressed + residual == corrected input,
exactly, for any deterministic C that returns a subset/projection of its
input.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .types import Tree, tree_zeros_like


class EFState(NamedTuple):
    error: Tree


def ef_init(template: Tree, dtype=jnp.float32) -> EFState:
    return EFState(error=tree_zeros_like(template, dtype=dtype))


def ef_apply(
    state: EFState,
    g: Tree,
    compress_fn: Callable[[jax.Array], jax.Array],
) -> tuple[Tree, EFState]:
    """Apply C to the error-corrected gradient; return (compressed, state').

    ``compress_fn`` maps a flat fp32 vector to its compressed *dense*
    representation (e.g. densified top-k). Residual accumulates in fp32.
    """

    def leaf(e, x):
        corrected = x.astype(e.dtype).reshape(-1) + e.reshape(-1)
        out = compress_fn(corrected)
        new_e = (corrected - out).reshape(e.shape)
        return out.reshape(x.shape).astype(x.dtype), new_e

    g_leaves, treedef = jax.tree.flatten(g)
    pairs = [leaf(e, x) for e, x in zip(jax.tree.leaves(state.error), g_leaves)]
    compressed = jax.tree.unflatten(treedef, [c for c, _ in pairs])
    new_state = EFState(error=jax.tree.unflatten(treedef, [e for _, e in pairs]))
    return compressed, new_state

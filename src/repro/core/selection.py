"""Adaptive aggregation: the LASG-style selection rule used by SASG (eq. 6).

Worker m uploads at step t iff

    || grad(w^t; xi_t) - grad(w^{t-tau_m}; xi_t) ||^2
        >  (1/M^2) * sum_{d=1..D} alpha_d * || w^{t+1-d} - w^{t-d} ||^2

or its staleness hit the cap (tau_m >= D). Crucially both gradients are
evaluated on the *same* minibatch xi_t (paper Section 3.2): this cancels the
non-diminishing stochastic-variance term that breaks the plain LAG rule in
stochastic settings.

The squared-parameter-difference window is a replicated (D,) vector pushed
once per global step; evaluating the rule is entirely worker-local (DESIGN.md
§2), so adaptivity costs zero extra communication.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from .types import Tree, tree_sq_norm, tree_sub


@dataclass(frozen=True)
class SelectionConfig:
    enabled: bool = True
    max_delay: int = 10                      # D (paper uses D=10)
    # alpha_d weights; if None, alpha_d = alpha_scale / lr at build time as in
    # the paper's experiments (alpha_d = 1/gamma or 1/(2 gamma)).
    alphas: Optional[Sequence[float]] = None
    alpha_scale: float = 1.0                 # alpha_d = alpha_scale / lr
    # Beyond-paper: probabilistic deadline skip for straggler mitigation; a
    # worker whose (simulated or measured) step time exceeds the deadline is
    # forced into the skip branch, which is exactly the algorithm's M_c path.
    deadline_skip: bool = False
    # Beyond-paper (EXPERIMENTS.md §Perf iter 4): evaluate rule (6) on a
    # probe sub-batch instead of the full minibatch. The paper's rule costs a
    # full auxiliary forward+backward (2x step compute AND 2x TP collective
    # traffic); probing at fraction p costs 2p extra instead of 1x. The
    # staleness cap D still bounds the worst case, so Theorem 1's D-bounded
    # delay analysis is unaffected; only the rule's variance grows.
    probe_fraction: float = 1.0


class SelectionState(NamedTuple):
    tau: jax.Array        # () int32, worker-local staleness counter
    window: jax.Array     # (D,) f32, replicated ||w^{t+1-d} - w^{t-d}||^2


def init_selection(cfg: SelectionConfig) -> SelectionState:
    return SelectionState(
        tau=jnp.ones((), jnp.int32),
        window=jnp.zeros((max(cfg.max_delay, 1),), jnp.float32),
    )


def resolve_alphas(cfg: SelectionConfig, lr: float) -> jax.Array:
    if cfg.alphas is not None:
        a = jnp.asarray(cfg.alphas, jnp.float32)
        assert a.shape == (cfg.max_delay,)
        return a
    return jnp.full((cfg.max_delay,), cfg.alpha_scale / max(lr, 1e-12), jnp.float32)


def should_send(
    cfg: SelectionConfig,
    g_fresh: Tree,
    g_stale: Tree,
    state: SelectionState,
    alphas: jax.Array,
    num_workers: int,
    force_skip: Optional[jax.Array] = None,
    diff_sq_norm=None,
) -> jax.Array:
    """Evaluate rule (6); returns a scalar bool (True => upload fresh grad).

    ``diff_sq_norm(a, b)`` overrides the default local ||a - b||^2: under
    payload-level stage sharding the trunk leaves are stage-local slices, so
    the transport supplies a stage-aware norm (psum of the trunk
    contribution over the stage axis) — every stage then evaluates the same
    lhs and the send decision agrees across stages by construction.
    """
    if diff_sq_norm is not None:
        lhs = diff_sq_norm(g_fresh, g_stale)
    else:
        lhs = tree_sq_norm(tree_sub(g_fresh, g_stale))
    rhs = jnp.sum(alphas * state.window) / float(num_workers) ** 2
    send = (lhs > rhs) | (state.tau >= cfg.max_delay)
    if force_skip is not None:
        # Straggler deadline: force the skip branch unless staleness capped.
        send = jnp.where(force_skip & (state.tau < cfg.max_delay), False, send)
    return send


def advance_tau(state: SelectionState, send: jax.Array) -> jax.Array:
    return jnp.where(send, jnp.ones_like(state.tau), state.tau + 1)


def push_window(state: SelectionState, update_sq_norm: jax.Array) -> jax.Array:
    """Shift in ||w^{t+1} - w^t||^2 as the newest window entry (d=1)."""
    return jnp.concatenate(
        [update_sq_norm.reshape(1).astype(jnp.float32), state.window[:-1]]
    )

"""Composable fault-injection DSL for the chaos harness (DESIGN.md §5).

A :class:`FaultPlan` is an immutable schedule of faults keyed on the
training-step index, with a deterministic seed — the same plan replays the
same fault sequence bit-for-bit, which is what lets the chaos suite assert
final-parameter bit-identity against an uninterrupted run.

Fault kinds and where the :class:`~repro.train.elastic.ElasticTrainer`
applies them:

==============  ==========================================================
``crash``        raise :class:`InjectedFault` before the step (node loss;
                 fired once, recovery restores + replays)
``worker_drop``  resize the worker axis down to ``workers`` (stateless:
                 re-applies after a post-crash rewind passes the step again)
``worker_join``  resize the worker axis up to ``workers`` (stateless)
``straggler``    force the LASG skip path for ``indices`` over ``duration``
                 steps (drives ``force_skip`` — the algorithm's own M_c
                 mechanism is the mitigation, no recovery involved)
``corrupt_ckpt`` flip bytes in a committed checkpoint leaf (fired once;
                 exercises the newest-*verified* restore fallback)
``save_fail``    arm the next checkpoint save to fail its first
                 ``attempts`` write attempts (fired once; ``attempts`` <=
                 the writer's retry budget recovers transparently, more
                 declares the checkpoint lost without killing the run)
``data_hiccup``  raise :class:`DataStreamError` from the batch fetch
                 (fired once; replayable streams make recovery lossless)
==============  ==========================================================

"Fired once" vs "stateless": faults that *raise or mutate disk* must not
re-fire when recovery rewinds the step counter past their step (an infinite
crash loop); membership/straggler faults are pure functions of the step
index and MUST re-apply on replay so a rewound run re-traces the same
membership history an uninterrupted run had.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """Simulated node failure raised by the crash fault."""


class DataStreamError(RuntimeError):
    """Simulated input-pipeline failure raised by the data_hiccup fault."""


_ONCE_KINDS = frozenset({"crash", "corrupt_ckpt", "save_fail", "data_hiccup"})
_STATELESS_KINDS = frozenset({"worker_drop", "worker_join", "straggler"})
KINDS = _ONCE_KINDS | _STATELESS_KINDS


@dataclass(frozen=True)
class Fault:
    kind: str
    step: int
    workers: int = 0                   # resize target (worker_drop/join)
    indices: Tuple[int, ...] = ()      # straggler worker ids (() = 1 random)
    duration: int = 1                  # straggler steps
    attempts: int = 1                  # save_fail failing write attempts
    target_step: Optional[int] = None  # corrupt_ckpt victim (None = newest)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.kind in ("worker_drop", "worker_join") and self.workers < 1:
            raise ValueError(f"{self.kind} needs workers >= 1")


@dataclass(frozen=True)
class FaultPlan:
    """Immutable fault schedule. Builder methods return extended copies, so
    plans compose by chaining (or ``plan_a + plan_b``)::

        plan = (FaultPlan(seed=7)
                .worker_drop(step=20, to=2)
                .worker_join(step=40, to=4)
                .crash(step=55))
    """

    faults: Tuple[Fault, ...] = ()
    seed: int = 0

    def _with(self, fault: Fault) -> "FaultPlan":
        return replace(self, faults=self.faults + (fault,))

    def crash(self, step: int) -> "FaultPlan":
        return self._with(Fault("crash", step))

    def worker_drop(self, step: int, to: int) -> "FaultPlan":
        return self._with(Fault("worker_drop", step, workers=to))

    def worker_join(self, step: int, to: int) -> "FaultPlan":
        return self._with(Fault("worker_join", step, workers=to))

    def straggler(
        self, step: int, indices: Tuple[int, ...] = (), duration: int = 1
    ) -> "FaultPlan":
        return self._with(
            Fault("straggler", step, indices=tuple(indices), duration=duration)
        )

    def corrupt_ckpt(self, step: int, target_step: Optional[int] = None) -> "FaultPlan":
        return self._with(Fault("corrupt_ckpt", step, target_step=target_step))

    def save_fail(self, step: int, attempts: int = 1) -> "FaultPlan":
        return self._with(Fault("save_fail", step, attempts=attempts))

    def data_hiccup(self, step: int) -> "FaultPlan":
        return self._with(Fault("data_hiccup", step))

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        if self.seed != other.seed:
            raise ValueError("cannot compose FaultPlans with different seeds")
        return replace(self, faults=self.faults + other.faults)

    def __len__(self) -> int:
        return len(self.faults)

    @classmethod
    def single_fault_matrix(
        cls,
        step: int = 7,
        workers: int = 4,
        save_retries: int = 2,
        seed: int = 0,
    ) -> Dict[str, "FaultPlan"]:
        """The chaos-matrix plans — one fault class per plan, all injected at
        ``step`` (which should land strictly between two checkpoint steps so
        recovery exercises real replay). ``corrupt_ckpt`` pairs the byte-flip
        with a crash at the same step: corruption alone is invisible until a
        restore happens."""
        return {
            "crash": cls(seed=seed).crash(step),
            "worker_drop": cls(seed=seed).worker_drop(step, to=max(workers // 2, 1)),
            "straggler": cls(seed=seed).straggler(step, duration=2),
            "corrupt_ckpt": cls(seed=seed).corrupt_ckpt(step).crash(step),
            "save_fail_transient": cls(seed=seed).save_fail(step, attempts=save_retries),
            "save_fail_lost": cls(seed=seed).save_fail(step, attempts=save_retries + 2),
            "data_hiccup": cls(seed=seed).data_hiccup(step),
        }


class FaultInjector:
    """Stateful reader of a :class:`FaultPlan` used by the ElasticTrainer.

    Fired-once bookkeeping applies only to ``_ONCE_KINDS`` (module
    docstring); membership and straggler queries are pure functions of the
    step index. Per-fault randomness (e.g. which worker straggles when
    ``indices`` is empty) derives from ``default_rng((seed, fault_index))``
    so it is stable across recovery replays.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired: set = set()

    def _take(self, step: int, kind: str) -> Optional[Fault]:
        """The first unfired fault of ``kind`` at ``step`` (marks it fired)."""
        for i, f in enumerate(self.plan.faults):
            if f.kind == kind and f.step == step and i not in self._fired:
                self._fired.add(i)
                return f
        return None

    # -- stateless (replayed on rewind) -----------------------------------

    def resize_to(self, step: int) -> Optional[int]:
        """Target worker count if a membership event is scheduled at step."""
        for f in self.plan.faults:
            if f.kind in ("worker_drop", "worker_join") and f.step == step:
                return f.workers
        return None

    def straggler_mask(self, step: int, num_workers: int) -> Optional[np.ndarray]:
        """(num_workers,) bool force_skip mask, or None when no straggler is
        active at ``step``. Active over [f.step, f.step + f.duration)."""
        mask = None
        for i, f in enumerate(self.plan.faults):
            if f.kind != "straggler" or not (f.step <= step < f.step + f.duration):
                continue
            if mask is None:
                mask = np.zeros(num_workers, bool)
            idx = f.indices or (
                int(np.random.default_rng((self.plan.seed, i)).integers(num_workers)),
            )
            for w in idx:
                mask[w % num_workers] = True
        return mask

    # -- fired-once (never replayed) --------------------------------------

    def crash_at(self, step: int) -> bool:
        return self._take(step, "crash") is not None

    def corrupt_at(self, step: int) -> Optional[Fault]:
        return self._take(step, "corrupt_ckpt")

    def save_fail_attempts(self, step: int) -> int:
        f = self._take(step, "save_fail")
        return f.attempts if f is not None else 0

    def data_hiccup_at(self, step: int) -> bool:
        return self._take(step, "data_hiccup") is not None


def corrupt_checkpoint(
    ckpt_dir: str,
    step: Optional[int] = None,
    rng: Optional[np.random.Generator] = None,
) -> Optional[int]:
    """Flip bytes in the middle of one leaf file of a committed checkpoint
    (newest when ``step`` is None). Returns the corrupted step, or None when
    no checkpoint exists. Payload bytes are flipped (not the npy header), so
    the file still loads — only the CRC check can catch it."""
    import os

    from . import checkpoint as CKPT

    steps = CKPT.candidate_steps(ckpt_dir)
    if not steps:
        return None
    victim = step if step is not None else steps[0]
    path = os.path.join(ckpt_dir, f"step_{victim}")
    npys = sorted(f for f in os.listdir(path) if f.endswith(".npy"))
    if not npys:
        return None
    rng = rng or np.random.default_rng(0)
    target = npys[int(rng.integers(len(npys)))]
    fpath = os.path.join(path, target)
    size = os.path.getsize(fpath)
    with open(fpath, "r+b") as f:
        # stay clear of the ~128-byte npy header so np.load still succeeds
        pos = max(size // 2, 192)
        if pos >= size:
            pos = size - 1
        f.seek(pos)
        chunk = f.read(min(8, size - pos))
        f.seek(pos)
        f.write(bytes(b ^ 0xFF for b in chunk))
    return victim

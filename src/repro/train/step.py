"""Training-step builder: model x SASG exchange x optimizer x mesh strategy.

The step has two nested domains (DESIGN.md §2):

  outer (auto/SPMD): parameter update, optimizer, window push, counters —
      everything replicated over worker axes and FSDP/TP sharded over the
      auto axes.
  inner (shard_map over strategy.worker_axes): per-worker gradients,
      selection rule, error feedback + compression, and the sparse
      all-gather exchange.

``plain`` strategy (no shard_map) is standard auto-SPMD data-parallel SGD —
used both as the non-SASG baseline and the fallback where worker replication
cannot fit (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm import bits as bits_lib
from repro.comm.transport import (
    ActivationLayout as TransportActivationLayout,
    StageInfo,
    supports_stage_payload,
)
from repro.core import metrics as CM
from repro.core.sasg import SASGConfig, build_exchange, update_global_state
from repro.core.types import (
    CommCounters,
    add_worker_axis,
    strip_worker_axis,
    tree_flatten_with_paths,
    tree_size,
    tree_sq_norm,
)
from repro.dist.pipeline import (
    build_pipelined_vag,
    build_stage_combine,
    resolve_microbatches,
)
from repro.dist.sharding import (
    ef_specs,
    param_specs,
    stage_only_spec,
    strip_stage_spec,
)
from repro.dist.strategy import Strategy
from repro.models.model import Model
from repro.optim import GradientTransformation, apply_updates


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    wstate: Any            # worker-stacked SASG state; () for plain
    gstate: Any
    counters: CommCounters
    rng: jax.Array


class BuiltStep(NamedTuple):
    step: Callable                     # pure: (state, batch) -> (state, metrics)
    init: Callable                     # (key) -> TrainState (sharded)
    jit_step: Callable                 # jitted/donating version of `step`
    state_shardings: Any
    batch_sharding_fn: Callable        # batch -> shardings tree
    exchange: Any
    strategy: Strategy
    bits_paper: float
    bits_wire: float
    param_specs: Any


# Knob: when True, worker-state shardings constrain only the worker dim and
# XLA propagates auto-axis shardings (workaround lever for partitioner bugs).
SIMPLE_WSTATE_SPECS = False


def pipeline_gather_bits(transport, params_shape, pdef, strategy, selection) -> float:
    """Static stage-axis GRADIENT-exchange wire bits per step per device.

    Honest about which path the built transport takes: on the payload-gather
    path it is one k-sized payload all-gather ((S-1)/S tiled) plus the tiny
    prepare-grad psum per grad computation; on the dense fallback it is the
    d-sized trunk all-gather + non-trunk psum per grad computation
    (``dist.pipeline.build_stage_combine``). Consumed by the train-step
    metrics (``pipe_gather_bits_step``) and the HLO audit's analytic pipe
    model, so both stay in sync with ``CM.PipelineCommModel``.
    """
    S = strategy.pipeline_stages
    # pipelined grad computations per step: fresh, plus the stale-params
    # auxiliary grad when selection is on (two probe grads when probing)
    n_combines = (
        1 if not selection.enabled
        else (3 if selection.probe_fraction < 1.0 else 2)
    )
    paths, leaves, _ = tree_flatten_with_paths(params_shape)
    trunk_pfx = ("/".join(str(k) for k in pdef.trunk_path),)

    def _under(pth, prefixes):
        return any(pth == p or pth.startswith(p + "/") for p in prefixes)

    def _dense_bits(prefixes, invert=False):
        return float(sum(
            leaf.size * jnp.dtype(leaf.dtype).itemsize * 8
            for pth, leaf in zip(paths, leaves)
            if _under(pth, prefixes) != invert
        ))

    if transport.stage is not None:
        trunk_wire = bits_lib.bucket_wire_bits(
            transport.bits_report(params_shape), trunk_pfx
        )
        prep_pfx = tuple("/".join(str(k) for k in p) for p in pdef.prepare_paths)
        return (
            (S - 1) / S * trunk_wire
            + n_combines * 2 * (S - 1) / S * _dense_bits(prep_pfx)
        )
    return n_combines * (
        (S - 1) / S * _dense_bits(trunk_pfx)
        + 2 * (S - 1) / S * _dense_bits(trunk_pfx, invert=True)
    )


def _worker_index(worker_axes):
    idx = jnp.zeros((), jnp.int32)
    for a in worker_axes:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _rep(tree):
    return jax.tree.map(lambda _x: P(), tree)


def _worker_stacked(tree, wa):
    return jax.tree.map(lambda x: P(wa, *([None] * (np.ndim(x) - 1))), tree)


def build_train_step(
    model: Model,
    sasg_cfg: SASGConfig,
    mesh,
    strategy: Strategy,
    lr_schedule: Callable,
    optimizer: Optional[GradientTransformation] = None,
    donate: bool = True,
) -> BuiltStep:
    if (
        strategy.uses_shard_map
        and strategy.fsdp_axis is not None
        and not compat.PARTIAL_AUTO_SHARD_MAP
    ):
        # Old JAX only: the compat full-manual degrade would silently
        # un-shard the params instead of reproducing the partitioner CHECK,
        # so refuse eagerly. On partial-auto-capable JAX the config reaches
        # XLA directly and tests/test_known_limits.py keeps probing whether
        # the CHECK is fixed (at which point hierarchical FSDP can return).
        raise NotImplementedError(
            f"FSDP over {strategy.fsdp_axis!r} inside the manual worker "
            "region hits an XLA SPMD partitioner CHECK "
            "(tests/test_known_limits.py); hierarchical SASG is TP-only — "
            "use fsdp_axis=None"
        )
    fold_lr = sasg_cfg.fold_lr and strategy.uses_shard_map
    M = strategy.num_workers
    waxes = strategy.worker_axes
    wa = (waxes if len(waxes) > 1 else (waxes[0] if waxes else None))

    # Pipeline composition: a stage axis only engages inside the worker
    # shard_map region, and needs the model's homogeneous trunk (PipelineDef)
    # to divide over the stages. choose_strategy applies soft fallbacks when
    # it is told the trunk depth; an incompatible hand-built Strategy is a
    # config error and fails eagerly here.
    stage = strategy.stage_axis if (
        strategy.pipelined and strategy.uses_shard_map
    ) else None
    pdef = model.pipeline
    if stage is not None:
        if pdef is None:
            raise ValueError(
                f"strategy requests pipeline_stages={strategy.pipeline_stages} "
                f"but model {model.config.name!r} has no PipelineDef "
                "(no homogeneous stage-stackable trunk)"
            )
        if pdef.n_layers % strategy.pipeline_stages != 0:
            raise ValueError(
                f"trunk depth {pdef.n_layers} does not divide over "
                f"{strategy.pipeline_stages} pipeline stages; pass "
                "trunk_layers to choose_strategy for the soft fallback"
            )
    trunk_paths = (tuple(str(k) for k in pdef.trunk_path),) if stage else ()

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(
        params_shape, mesh, strategy.fsdp_axis, strategy.tp_axis,
        stage_axis=stage, trunk_paths=trunk_paths,
    )

    def _stage_only(spec):
        """The manual-stage part of a param spec (trunk stacked dim)."""
        return stage_only_spec(spec, stage)

    def _no_stage(spec):
        """A param spec with the manual stage axis stripped (auto axes only)."""
        return strip_stage_spec(spec, stage)

    # Payload-gather hot path: when the compressor supports stage-local
    # encoding (block-local per_shard topk_ef) and the model's prepare/finish
    # param reads are disjoint, the trunk gradient is NEVER stage-gathered —
    # gradients stay stage-sliced, the transport compresses the local slice,
    # and only the k-sized payload crosses the stage axis. Everything else
    # (per_tensor/flat layouts, randk/qsgd/dense compressors, tied-embedding
    # models) takes the dense stage-combine fallback.
    payload_mode = (
        stage is not None
        and pdef.prepare_paths is not None
        and supports_stage_payload(sasg_cfg.compressor)
    )
    stage_info = None
    if payload_mode:
        _prefixes = tuple("/".join(p) for p in (trunk_paths or ()))
        _tpaths, _tleaves, _ = tree_flatten_with_paths(params_shape)
        trunk_dims = {
            pth: leaf.shape[0]
            for pth, leaf in zip(_tpaths, _tleaves)
            if any(pth == p or pth.startswith(p + "/") for p in _prefixes)
        }
        stage_info = StageInfo(
            axis=stage, num_stages=strategy.pipeline_stages,
            trunk_prefixes=_prefixes, trunk_dims=trunk_dims,
        )

    vag = jax.value_and_grad(model.loss_fn)
    # Inside the worker region, pipelined strategies swap value_and_grad for
    # the stage-pipelined version. On the fallback path the per-stage
    # gradient combine (trunk all-gather + stage-0-masked psum) is NOT fused
    # into the vag: it is threaded into the exchange as the transport's
    # stage composition (repro.comm.Transport.gather), so the exchange
    # always operates on — and densifies against — the FULL gradient tree.
    # On the payload path the vag itself is stage-local (stop-gradient loss
    # mask, dist.pipeline.build_pipelined_loss) and no dense combine exists.
    worker_vag = (
        build_pipelined_vag(
            pdef, stage, strategy.microbatches,
            combine=False, stage_local=payload_mode,
            act_layout=sasg_cfg.act_layout, engine=sasg_cfg.pipeline_engine,
        )
        if stage is not None else vag
    )
    stage_combine = (
        build_stage_combine(pdef, stage)
        if stage is not None and not payload_mode else None
    )

    if strategy.uses_shard_map:
        # inner_dp stays an AUTO axis: the in-pod gradient mean over it is the
        # automatic backward psum of the batch sharding — no manual reduce.
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        # the exchange's leaf specs never carry the manual stage axis: on
        # the fallback path the exchange sees the FULL gradient tree (trunk
        # gathered over stages first), and on the payload path the
        # stage-local slice must use the SAME TP-only blocked geometry as
        # the flat run (support-exactness) — either way, a stage entry in
        # the specs would diverge payload sizing from the non-pipelined run
        exchange = build_exchange(
            sasg_cfg,
            worker_axes=waxes,
            reduce_axes=(),
            num_workers=M,
            leaf_specs=jax.tree.map(
                _no_stage, pspecs, is_leaf=lambda x: isinstance(x, P)
            ),
            axis_sizes=axis_sizes,
            grad_combine=stage_combine,
            stage=stage_info,
        )
        bits_paper = exchange.bits_per_upload_paper(params_shape)
        bits_wire = exchange.bits_per_upload_wire(params_shape)
    else:
        exchange = None
        bits_paper = bits_wire = 32.0 * tree_size(params_shape)

    # Static stage-axis GRADIENT-exchange wire bits per step (per device),
    # honest about which path is taken. Ring (activation) traffic is modeled
    # separately inside the step (it depends on the batch shape).
    gather_bits_step = 0.0
    if stage is not None and strategy.uses_shard_map:
        gather_bits_step = pipeline_gather_bits(
            exchange.transport, params_shape, pdef, strategy,
            sasg_cfg.selection,
        )

    # ------------------------------------------------------------------
    # init + shardings
    # ------------------------------------------------------------------
    def init_all(key):
        params = model.init(key)
        opt_state = optimizer.init(params) if optimizer is not None else ()
        if strategy.uses_shard_map:
            ws = exchange.init_worker(params)
            wstate = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    jnp.asarray(x)[None], (M,) + jnp.asarray(x).shape
                ),
                ws,
            )
            gstate = exchange.init_global()
        else:
            wstate, gstate = (), ()
        return TrainState(params, opt_state, wstate, gstate,
                          CommCounters.zeros(), key)

    state_shape = jax.eval_shape(init_all, jax.random.PRNGKey(0))

    def _opt_specs(os_shape):
        """Optimizer moments mirror param specs (keys mu/m/v); rest replicated."""
        pstruct = jax.tree.structure(params_shape)

        def rec(t):
            if isinstance(t, dict):
                return {
                    k: (pspecs if (k in ("mu", "m", "v")
                                   and jax.tree.structure(v) == pstruct) else rec(v))
                    for k, v in t.items()
                }
            if isinstance(t, (tuple, list)):
                return type(t)(rec(v) for v in t)
            return jax.tree.map(lambda _x: P(), t)

        return rec(os_shape)

    def _wstate_specs(ws_shape):
        """Worker dim over worker axes; stale_params additionally reuse param
        specs on their trailing dims (they ARE param-shaped, stage sharding
        included — they must mirror the params the pipelined forward slices).
        comp_state (EF buffers): stage-SHARDED on the payload-gather path
        (each stage owns its trunk slice's residuals, dist.sharding.ef_specs)
        and stage-replicated auto-axis specs on the dense-combine fallback.
        Either way the checkpointed logical array keeps the FULL trunk shape,
        so restore across stage counts is pure resharding."""
        base = _worker_stacked(ws_shape, wa)
        if not strategy.uses_shard_map or SIMPLE_WSTATE_SPECS:
            return base
        ef_pspecs = ef_specs(pspecs, stage, payload_mode)
        try:
            if jax.tree.structure(ws_shape.stale_params) == jax.tree.structure(params_shape):
                stale = jax.tree.map(
                    lambda x, ps: P(wa, *tuple(ps)), ws_shape.stale_params, pspecs
                )
                base = base._replace(stale_params=stale)
            if jax.tree.structure(ws_shape.comp_state) == jax.tree.structure(params_shape):
                err = jax.tree.map(
                    lambda x, ps: P(wa, *tuple(ps)),
                    ws_shape.comp_state, ef_pspecs,
                )
                base = base._replace(comp_state=err)
        except (AttributeError, ValueError):
            pass
        return base

    state_pspec = TrainState(
        params=pspecs,
        opt_state=_opt_specs(state_shape.opt_state),
        wstate=_wstate_specs(state_shape.wstate) if strategy.uses_shard_map else (),
        gstate=_rep(state_shape.gstate),
        counters=_rep(state_shape.counters),
        rng=P(),
    )
    to_sharding = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    state_shardings = to_sharding(state_pspec)

    def batch_sharding_fn(batch):
        ba = tuple(strategy.batch_axes)
        bspec = ba if len(ba) > 1 else (ba[0] if ba else None)
        return jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(bspec, *([None] * (np.ndim(x) - 1)))
            ),
            batch,
        )

    # ------------------------------------------------------------------
    # the step
    # ------------------------------------------------------------------
    if strategy.uses_shard_map:

        def worker_fn(params, batch, wstate, gstate, lr, key, fs=None):
            wstate = strip_worker_axis(wstate)
            if strategy.inner_dp and compat.PARTIAL_AUTO_SHARD_MAP:
                batch = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, P(strategy.inner_dp, *([None] * (x.ndim - 1)))
                    ),
                    batch,
                )
            key = jax.random.fold_in(key, _worker_index(waxes))
            # fs: replicated (M,) bool straggler mask (train.faults) — each
            # worker picks its own flag; None traces exactly the unfaulted
            # program (no gates added)
            force_skip = fs[_worker_index(waxes)] if fs is not None else None
            update, new_wstate, info = exchange.run(
                params, batch, wstate, gstate, lr, key, worker_vag,
                force_skip=force_skip,
            )
            # pin the densified update to the parameter sharding over the
            # AUTO axes (otherwise XLA replicates the fp32 update tree —
            # 32 GB/device on llama3-8b; EXPERIMENTS.md §Perf iteration 1)
            manual_set = set(waxes) | ({stage} if stage is not None else set())

            def _strip_manual(spec):
                out = []
                for entry in tuple(spec):
                    names = entry if isinstance(entry, tuple) else (entry,)
                    if entry is not None and any(n in manual_set for n in names):
                        out.append(None)
                    else:
                        out.append(entry)
                return P(*out)

            if compat.PARTIAL_AUTO_SHARD_MAP:
                update = jax.tree.map(
                    lambda u, s: jax.lax.with_sharding_constraint(u, _strip_manual(s)),
                    update, pspecs,
                )
            return update, add_worker_axis(new_wstate), add_worker_axis(info)

        def _params_region_specs(params):
            """shard_map specs for the params input: replicated over worker
            axes; trunk leaves stage-sliced when pipelining (each stage gets
            its contiguous block of stacked layers)."""
            if stage is None:
                return _rep(params)
            return jax.tree.map(
                _stage_only, pspecs, is_leaf=lambda x: isinstance(x, P)
            )

        def _wstate_region_specs(ws):
            """shard_map specs for the worker state: worker dim over worker
            axes; stale_params additionally stage-sliced on the trunk so they
            mirror the params tree the pipelined grad_fn consumes. On the
            payload-gather path the EF buffers (comp_state) are stage-sliced
            the same way: encode sees the residuals of exactly the trunk
            slice it compresses."""
            base = _worker_stacked(ws, wa)
            if stage is None:
                return base
            try:
                if jax.tree.structure(ws.stale_params) == jax.tree.structure(params_shape):
                    stale = jax.tree.map(
                        lambda x, ps: P(wa, *tuple(_stage_only(ps))),
                        ws.stale_params, pspecs,
                    )
                    base = base._replace(stale_params=stale)
                if payload_mode and (
                    jax.tree.structure(ws.comp_state)
                    == jax.tree.structure(params_shape)
                ):
                    err = jax.tree.map(
                        lambda x, ps: P(wa, *tuple(_stage_only(ps))),
                        ws.comp_state, pspecs,
                    )
                    base = base._replace(comp_state=err)
            except (AttributeError, ValueError):
                pass
            return base

        def step(state: TrainState, batch, force_skip=None):
            lr = lr_schedule(state.gstate.step)
            key = jax.random.fold_in(state.rng, state.gstate.step)

            in_specs = (
                _params_region_specs(state.params),
                _worker_stacked(batch, wa),
                _wstate_region_specs(state.wstate),
                _rep(state.gstate),
                P(),
                P(),
            )
            if force_skip is not None:
                in_specs = in_specs + (P(),)  # replicated (M,) bool mask
            # outputs: update (params-structured, replicated), worker state
            # (same structure as input, worker-stacked), info (5 scalars with
            # a singleton worker dim)
            from repro.core.sasg import ExchangeInfo

            out_specs = (
                _rep(state.params),
                _wstate_region_specs(state.wstate),
                ExchangeInfo(*([P(wa)] * len(ExchangeInfo._fields))),
            )
            manual = set(waxes) | ({stage} if stage is not None else set())
            sm = jax.shard_map(
                worker_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                axis_names=manual, check_vma=False,
            )
            args = (state.params, batch, state.wstate, state.gstate, lr, key)
            if force_skip is not None:
                args = args + (jnp.asarray(force_skip, bool),)
            update, wstate, info = sm(*args)

            if fold_lr:
                delta, opt_state = update, state.opt_state
            else:
                delta, opt_state = optimizer.update(update, state.opt_state, state.params)
            new_params = apply_updates(state.params, delta)
            gstate = update_global_state(state.gstate, tree_sq_norm(delta))
            num_sent = info.num_sent[0]
            counters = CM.accumulate(state.counters, num_sent, bits_paper, bits_wire)
            mets = {
                "loss": jnp.mean(info.loss),
                "num_sent": num_sent,
                "lr": lr,
                "rounds_total": counters.rounds,
                "bits_paper_total": counters.bits_paper,
                "bits_wire_total": counters.bits_wire,
            }
            if stage is not None:
                # static per-stage ring traffic (CM.PipelineCommModel), every
                # step, independent of the send/skip decisions. Engine-aware:
                # the 1F1B ring moves ActivationLayout wire parts (compressed
                # hop + broadcast payload bits); GPipe moves dense microbatch
                # activations per tick.
                wbatch = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        (x.shape[0] // M,) + x.shape[1:], x.dtype
                    ),
                    batch,
                )
                pshape = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.params
                )
                h = jax.eval_shape(pdef.prepare, pshape, wbatch)
                nm = resolve_microbatches(
                    h.shape[0], strategy.microbatches or strategy.pipeline_stages
                )
                act_elems = int(np.prod(h.shape)) // nm
                layout = sasg_cfg.act_layout or TransportActivationLayout()
                pipe = CM.PipelineCommModel(
                    stages=strategy.pipeline_stages, n_micro=nm,
                    act_elems=act_elems,
                    bits_per_elem=h.dtype.itemsize * 8,
                    gather_bits=gather_bits_step,
                    engine=sasg_cfg.pipeline_engine,
                    hop_payload_bits=layout.payload_bits(act_elems),
                    bcast_payload_bits=layout.payload_bits(nm * act_elems),
                )
                mets["pipe_stages"] = jnp.float32(strategy.pipeline_stages)
                mets["pipe_ring_bits_step"] = jnp.float32(pipe.ring_bits_per_step())
                mets["pipe_gather_bits_step"] = jnp.float32(pipe.gather_bits)
                mets["pipe_bits_step"] = jnp.float32(pipe.bits_per_step())
                mets["pipe_bits_total"] = (
                    jnp.float32(pipe.bits_per_step()) * gstate.step.astype(jnp.float32)
                )
            return (
                TrainState(new_params, opt_state, wstate, gstate, counters, state.rng),
                mets,
            )

    else:

        def step(state: TrainState, batch, force_skip=None):
            # plain SPMD has no selection rule: a straggler mask is meaningless
            # (every worker contributes to the dense psum) and is ignored
            count = state.counters.rounds.astype(jnp.int32)
            lr = lr_schedule(count)
            loss, grads = vag(state.params, batch)
            if optimizer is not None:
                delta, opt_state = optimizer.update(grads, state.opt_state, state.params)
            else:
                delta = jax.tree.map(lambda g: lr * g.astype(jnp.float32), grads)
                opt_state = state.opt_state
            new_params = apply_updates(state.params, delta)
            counters = CM.accumulate(state.counters, jnp.float32(1.0), bits_paper, bits_wire)
            mets = {
                "loss": loss,
                "num_sent": jnp.float32(1.0),
                "lr": lr,
                "rounds_total": counters.rounds,
                "bits_paper_total": counters.bits_paper,
                "bits_wire_total": counters.bits_wire,
            }
            return (
                TrainState(new_params, opt_state, (), (), counters, state.rng),
                mets,
            )

    def jit_step(state, batch, force_skip=None):
        # jax.jit caches wrappers on (fun, options): the no-mask call builds
        # the SAME jitted program as before this arg existed, and the masked
        # call gets its own cached 3-arg wrapper (used by the straggler
        # fault path; mask is a traced (M,) bool so flipping workers between
        # steps does NOT retrace)
        if force_skip is None:
            fn = jax.jit(
                step,
                in_shardings=(state_shardings, batch_sharding_fn(batch)),
                out_shardings=(state_shardings, None),
                donate_argnums=(0,) if donate else (),
            )
            return fn(state, batch)
        fn = jax.jit(
            step,
            in_shardings=(
                state_shardings,
                batch_sharding_fn(batch),
                NamedSharding(mesh, P()),
            ),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,) if donate else (),
        )
        return fn(state, batch, jnp.asarray(force_skip, bool))

    def init(key):
        if compat.HAS_AXIS_TYPES:
            # modern jaxlib: partitionable threefry makes sharded-output RNG
            # value-stable, so the state can be born sharded (no replicated
            # transient — required for models that only fit sharded)
            return jax.jit(init_all, out_shardings=state_shardings)(key)
        # Pinned 0.4.x jaxlib: jit(out_shardings=...) partitions the threefry
        # computation and yields global values that differ from the eager
        # init (observed as a stage-count factor on stage-sharded trunk
        # leaves). Initialize unsharded, then lay out with device_put — pure
        # data movement, value-exact — at the cost of one transiently
        # replicated state. Fine on the CPU test meshes; ROADMAP tracks
        # re-verifying the direct sharded init after a jaxlib upgrade.
        return jax.device_put(jax.jit(init_all)(key), state_shardings)

    return BuiltStep(
        step=step,
        init=init,
        jit_step=jit_step,
        state_shardings=state_shardings,
        batch_sharding_fn=batch_sharding_fn,
        exchange=exchange,
        strategy=strategy,
        bits_paper=bits_paper,
        bits_wire=bits_wire,
        param_specs=pspecs,
    )

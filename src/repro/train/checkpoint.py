"""Self-contained sharded checkpointing (no orbax in this container).

Format: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
``manifest.json`` (treedef, leaf paths, dtypes/shapes, checksums, step, and
a caller ``meta`` dict — the Trainer records the SASG worker count so an
elastic restore knows when to re-initialize per-worker state). Writes are
atomic (tmp dir + rename) and optionally asynchronous (background thread;
the trainer only blocks on the previous save).

Failure contract: the writer retries with exponential backoff
(``retries``/``backoff``); if every attempt fails, the returned
:class:`SaveHandle`'s ``join()`` raises :class:`CheckpointSaveError` — a
dead writer thread is never silently indistinguishable from a successful
save. Restore re-places leaves under any sharding/mesh — this is the
elastic-resize path: a checkpoint taken on one mesh restores onto another,
and SASG worker state is re-initialized when the worker count changes
(theory-safe: a fresh error-feedback start, DESIGN.md §5).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional

import jax
import numpy as np

_FLAG = "__ckpt_leaf__"


class CheckpointSaveError(RuntimeError):
    """Raised from ``SaveHandle.join()`` when every write attempt failed."""

    def __init__(self, step: int, cause: BaseException):
        super().__init__(
            f"checkpoint step_{step} could not be written: "
            f"{type(cause).__name__}: {cause}"
        )
        self.step = step
        self.cause = cause


class SaveHandle:
    """Async-save handle. ``join()`` re-raises writer failures instead of
    letting the Trainer join a dead thread and believe the save succeeded."""

    def __init__(self, thread: threading.Thread, step: int):
        self._thread = thread
        self.step = step
        self.error: Optional[CheckpointSaveError] = None

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)
        if self.error is not None:
            raise self.error

    def is_alive(self) -> bool:
        return self._thread.is_alive()


def _paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for keypath, leaf in flat:
        name = "_".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath
        )
        out.append((name, leaf))
    return out, treedef


def save(
    tree: Any,
    directory: str,
    step: int,
    blocking: bool = True,
    meta: Optional[dict] = None,
    retries: int = 2,
    backoff: float = 0.05,
    fail_attempts: int = 0,
) -> SaveHandle:
    """Serialize `tree` to <directory>/step_<step>. Returns a SaveHandle.

    ``meta`` is stored verbatim in the manifest (JSON-serializable).
    ``fail_attempts`` is a fault-injection knob (``train.faults``): the first
    N write attempts raise before touching disk, exercising the retry path.
    """
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"

    def _write_once():
        if os.path.exists(tmp):  # debris from a previous failed attempt
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _paths_and_leaves(host_tree)
        manifest = {"step": step, "meta": dict(meta or {}), "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            fname = f"{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"].append(
                {
                    "name": name,
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "crc": hashlib.md5(np.ascontiguousarray(leaf).tobytes()).hexdigest(),
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    def _run():
        last: Optional[BaseException] = None
        for attempt in range(retries + 1):
            try:
                if attempt < fail_attempts:
                    raise OSError(
                        f"injected save failure (attempt {attempt + 1})"
                    )
                _write_once()
                return
            except Exception as e:
                last = e
                shutil.rmtree(tmp, ignore_errors=True)
                if attempt < retries:
                    time.sleep(backoff * (2 ** attempt))
        handle.error = CheckpointSaveError(step, last)

    t = threading.Thread(target=_run)
    handle = SaveHandle(t, step)
    t.start()
    if blocking:
        handle.join()
    return handle


def candidate_steps(directory: str) -> List[int]:
    """Committed checkpoint steps, newest first — the restore fallback
    order: callers walk the list until one verifies. In-flight ``.tmp``
    writes and manifest-less debris are never candidates."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return sorted(steps, reverse=True)


def latest_step(directory: str) -> Optional[int]:
    steps = candidate_steps(directory)
    return steps[0] if steps else None


def manifest_meta(directory: str, step: int) -> dict:
    """The ``meta`` dict recorded at save time ({} for old checkpoints)."""
    try:
        with open(os.path.join(directory, f"step_{step}", "manifest.json")) as f:
            manifest = json.load(f)
        return dict(manifest.get("meta") or {})
    except (OSError, json.JSONDecodeError):
        return {}


def verify(directory: str, step: int) -> bool:
    path = os.path.join(directory, f"step_{step}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        for entry in manifest["leaves"]:
            leaf = np.load(os.path.join(path, entry["file"]))
            if hashlib.md5(np.ascontiguousarray(leaf).tobytes()).hexdigest() != entry["crc"]:
                return False
        return True
    except (OSError, json.JSONDecodeError, KeyError, ValueError):
        # ValueError: np.load on a truncated/garbled .npy (corrupt header)
        return False


def restore(
    template: Any,
    directory: str,
    step: int,
    shardings: Any = None,
    strict_worker_dim: bool = False,
) -> Any:
    """Restore into the structure of `template`. Leaves whose shapes mismatch
    (e.g. SASG worker-stacked state after an elastic resize) fall back to the
    template's value unless strict_worker_dim."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    files = [e["file"] for e in manifest["leaves"]]

    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(files) != len(t_leaves):
        raise ValueError(
            f"checkpoint has {len(files)} leaves, template has {len(t_leaves)}"
        )
    s_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(t_leaves)
    )
    out = []
    for f, t, s in zip(files, t_leaves, s_leaves):
        arr = np.load(os.path.join(path, f))
        tshape = tuple(np.shape(t))
        if tuple(arr.shape) != tshape:
            if strict_worker_dim:
                raise ValueError(f"shape mismatch {arr.shape} vs {tshape}")
            arr = np.asarray(t)  # elastic remap: re-init this leaf
        arr = arr.astype(np.dtype(jax.numpy.result_type(t)))
        out.append(jax.device_put(arr, s) if s is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_old(directory: str, keep: int = 3):
    """Drop all but the newest ``keep`` committed checkpoints.

    Safe against an in-flight async save: ``.tmp`` directories (a pending
    atomic rename) are never candidates, and the newest committed steps are
    always retained, so a rename landing mid-GC can only ever ADD a step
    that is immediately in the kept set."""
    steps = sorted(candidate_steps(directory))
    for s in steps[:-keep] if keep > 0 else steps:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)

"""Self-contained sharded checkpointing (no orbax in this container).

Format: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
``manifest.json`` (treedef, leaf paths, dtypes/shapes, checksums, step).
Writes are atomic (tmp dir + rename) and optionally asynchronous (background
thread; the trainer only blocks on the previous save). Restore re-places
leaves under any sharding/mesh — this is the elastic-resize path: a
checkpoint taken on one mesh restores onto another, and SASG worker state is
re-initialized when the worker count changes (theory-safe: a fresh error
-feedback start, DESIGN.md §5).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_FLAG = "__ckpt_leaf__"


def _paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for keypath, leaf in flat:
        name = "_".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath
        )
        out.append((name, leaf))
    return out, treedef


def save(tree: Any, directory: str, step: int, blocking: bool = True) -> threading.Thread:
    """Serialize `tree` to <directory>/step_<step>. Returns the writer thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

    def _write():
        final = os.path.join(directory, f"step_{step}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _paths_and_leaves(host_tree)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            fname = f"{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"].append(
                {
                    "name": name,
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "crc": hashlib.md5(np.ascontiguousarray(leaf).tobytes()).hexdigest(),
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    t = threading.Thread(target=_write)
    t.start()
    if blocking:
        t.join()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def verify(directory: str, step: int) -> bool:
    path = os.path.join(directory, f"step_{step}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        for entry in manifest["leaves"]:
            leaf = np.load(os.path.join(path, entry["file"]))
            if hashlib.md5(np.ascontiguousarray(leaf).tobytes()).hexdigest() != entry["crc"]:
                return False
        return True
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def restore(
    template: Any,
    directory: str,
    step: int,
    shardings: Any = None,
    strict_worker_dim: bool = False,
) -> Any:
    """Restore into the structure of `template`. Leaves whose shapes mismatch
    (e.g. SASG worker-stacked state after an elastic resize) fall back to the
    template's value unless strict_worker_dim."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    files = [e["file"] for e in manifest["leaves"]]

    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(files) != len(t_leaves):
        raise ValueError(
            f"checkpoint has {len(files)} leaves, template has {len(t_leaves)}"
        )
    s_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(t_leaves)
    )
    out = []
    for f, t, s in zip(files, t_leaves, s_leaves):
        arr = np.load(os.path.join(path, f))
        tshape = tuple(np.shape(t))
        if tuple(arr.shape) != tshape:
            if strict_worker_dim:
                raise ValueError(f"shape mismatch {arr.shape} vs {tshape}")
            arr = np.asarray(t)  # elastic remap: re-init this leaf
        arr = arr.astype(np.dtype(jax.numpy.result_type(t)))
        out.append(jax.device_put(arr, s) if s is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_old(directory: str, keep: int = 3):
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)

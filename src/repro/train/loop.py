"""Fault-tolerant training loop.

- periodic async checkpointing (atomic commit, keep-last-N GC);
- automatic restore-and-continue on step failure (node-failure simulation:
  a fault hook can raise mid-run and the Trainer recovers from the last
  valid checkpoint);
- straggler hook: a per-step deadline flag is forwarded into the SASG
  selection rule as force_skip (the algorithm's own M_c path doubles as the
  mitigation mechanism — DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import jax

from . import checkpoint as CKPT
from .step import BuiltStep, TrainState


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    max_restarts: int = 3


class Trainer:
    def __init__(
        self,
        built: BuiltStep,
        data: Iterator[dict],
        cfg: TrainerConfig,
        fault_hook: Optional[Callable[[int], None]] = None,
        log_fn: Callable[[str], None] = print,
    ):
        self.built = built
        self.data = data
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.log = log_fn
        self._save_thread = None
        self.history: list[dict] = []

    # -- checkpointing -----------------------------------------------------

    def _maybe_ckpt(self, state: TrainState, step: int, force=False):
        c = self.cfg
        if not c.ckpt_dir:
            return
        if force or (step > 0 and step % c.ckpt_every == 0):
            if self._save_thread is not None:
                self._save_thread.join()  # backpressure: one in flight
            self._save_thread = CKPT.save(
                state, c.ckpt_dir, step, blocking=not c.ckpt_async
            )
            CKPT.gc_old(c.ckpt_dir, c.ckpt_keep)

    def _restore_latest(self, template: TrainState) -> tuple[TrainState, int]:
        c = self.cfg
        step = CKPT.latest_step(c.ckpt_dir) if c.ckpt_dir else None
        if step is None:
            return template, 0
        if not CKPT.verify(c.ckpt_dir, step):
            self.log(f"[trainer] checkpoint step_{step} failed verification; skipping")
            return template, 0
        state = CKPT.restore(
            template, c.ckpt_dir, step, shardings=self.built.state_shardings
        )
        self.log(f"[trainer] restored checkpoint at step {step}")
        return state, step

    # -- main loop ----------------------------------------------------------

    def run(self, init_key=None, state: Optional[TrainState] = None) -> TrainState:
        c = self.cfg
        if state is None:
            state = self.built.init(init_key if init_key is not None else jax.random.PRNGKey(0))
        state, start = self._restore_latest(state)

        step = start
        restarts = 0
        while step < c.total_steps:
            try:
                batch = next(self.data)
                if self.fault_hook is not None:
                    self.fault_hook(step)  # may raise (simulated node failure)
                state, mets = self.built.jit_step(state, batch)
                if step % c.log_every == 0 or step == c.total_steps - 1:
                    loss = float(mets["loss"])
                    sent = float(mets["num_sent"])
                    self.log(
                        f"[trainer] step {step:5d} loss {loss:8.4f} "
                        f"sent {sent:4.0f}/{max(self.built.strategy.num_workers,1)} "
                        f"rounds {float(mets['rounds_total']):9.0f} "
                        f"bits(paper) {float(mets['bits_paper_total']):.3e}"
                    )
                self.history.append({k: float(v) for k, v in mets.items()})
                step += 1
                self._maybe_ckpt(state, step)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # node failure / data failure: recover
                restarts += 1
                if restarts > c.max_restarts:
                    raise
                self.log(f"[trainer] step {step} failed ({type(e).__name__}: {e}); "
                         f"recovering ({restarts}/{c.max_restarts})")
                template = self.built.init(jax.random.PRNGKey(0))
                state, step = self._restore_latest(template)
        self._maybe_ckpt(state, step, force=True)
        if self._save_thread is not None:
            self._save_thread.join()
        return state

"""Fault-tolerant training loop.

- periodic async checkpointing (atomic commit, keep-last-N GC) with
  surfaced save failures: the writer retries with backoff and a checkpoint
  that still cannot be written is declared LOST (logged + recorded in
  ``events``) instead of silently pretending success — a lost checkpoint
  never rolls back training, it only widens the replay window of the next
  recovery;
- automatic restore-and-continue on step failure, falling back through
  checkpoint candidates newest-first until one passes ``verify`` (a corrupt
  latest checkpoint costs replay distance, not the run);
- deterministic replay: recovery reseeks the data source to the restored
  step (``repro.data.ReplayableStream``), so the batch sequence an
  interrupted run consumes is identical to an uninterrupted one — zero
  skipped, zero duplicated. Non-seekable iterators keep the legacy lossy
  behavior with a one-time warning;
- straggler hook: a per-step worker mask is forwarded into the SASG
  selection rule as force_skip (the algorithm's own M_c path doubles as the
  mitigation mechanism — DESIGN.md §5);
- subclass hooks (``_pre_step`` / ``_fetch_batch`` / ``_force_skip``) are
  the extension surface used by ``train.elastic.ElasticTrainer`` for in-run
  membership resizes and fault injection.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import jax

from . import checkpoint as CKPT
from .step import BuiltStep, TrainState


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    max_restarts: int = 3
    record_batches: bool = False  # log (step, fingerprint) per applied batch


class Trainer:
    def __init__(
        self,
        built: BuiltStep,
        data: Iterator[dict],
        cfg: TrainerConfig,
        fault_hook: Optional[Callable[[int], None]] = None,
        log_fn: Callable[[str], None] = print,
    ):
        self.built = built
        self.data = data
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.log = log_fn
        self._save_handle: Optional[CKPT.SaveHandle] = None
        self._ckpt_fail_attempts = 0  # armed by fault injection (save_fail)
        self._init_key = None
        self._warned_unseekable = False
        self.history: list[dict] = []
        self.events: list[dict] = []      # resizes, recoveries, lost ckpts
        self.batch_log: list[tuple] = []  # (step, fingerprint) when recording

    # -- checkpointing -----------------------------------------------------

    def _ckpt_meta(self) -> dict:
        # the restore path needs the worker count to decide whether SASG
        # worker state can be carried or must be re-initialized (elastic)
        return {"num_workers": self.built.strategy.num_workers}

    def _join_save(self):
        """Block on the in-flight async save; surface (never swallow) its
        failure. A lost checkpoint is an event, not a training error."""
        if self._save_handle is None:
            return
        handle, self._save_handle = self._save_handle, None
        try:
            handle.join()
        except CKPT.CheckpointSaveError as e:
            self.log(f"[trainer] checkpoint LOST: {e}")
            self.events.append(
                {"kind": "ckpt_lost", "step": handle.step, "error": str(e.cause)}
            )

    def _maybe_ckpt(self, state: TrainState, step: int, force=False):
        c = self.cfg
        if not c.ckpt_dir:
            return
        if force or (step > 0 and step % c.ckpt_every == 0):
            self._join_save()  # backpressure: one in flight
            fail_attempts, self._ckpt_fail_attempts = self._ckpt_fail_attempts, 0
            try:
                handle = CKPT.save(
                    state, c.ckpt_dir, step, blocking=not c.ckpt_async,
                    meta=self._ckpt_meta(), fail_attempts=fail_attempts,
                )
            except CKPT.CheckpointSaveError as e:  # blocking save exhausted retries
                self.log(f"[trainer] checkpoint LOST: {e}")
                self.events.append(
                    {"kind": "ckpt_lost", "step": step, "error": str(e.cause)}
                )
            else:
                if c.ckpt_async:
                    self._save_handle = handle
            CKPT.gc_old(c.ckpt_dir, c.ckpt_keep)

    def _restore_latest(self, template: TrainState) -> tuple[TrainState, int]:
        """Newest *verified* checkpoint, falling back through older
        candidates when verification fails (corrupt/truncated files)."""
        c = self.cfg
        if not c.ckpt_dir:
            return template, 0
        for step in CKPT.candidate_steps(c.ckpt_dir):
            if not CKPT.verify(c.ckpt_dir, step):
                self.log(
                    f"[trainer] checkpoint step_{step} failed verification; "
                    "trying an older one"
                )
                continue
            state = CKPT.restore(
                template, c.ckpt_dir, step, shardings=self.built.state_shardings
            )
            saved_m = CKPT.manifest_meta(c.ckpt_dir, step).get("num_workers")
            m = self.built.strategy.num_workers
            if (
                self.built.strategy.uses_shard_map
                and saved_m is not None
                and saved_m != m
            ):
                # elastic restart: the checkpoint's worker set is gone, so
                # per-worker state restores as template debris — re-init it
                # from the RESTORED params (same cold start the in-run
                # resize uses, DESIGN.md §5)
                from .elastic import fresh_worker_state

                state = state._replace(
                    wstate=fresh_worker_state(self.built, state.params)
                )
                self.log(
                    f"[trainer] worker count changed {saved_m} -> {m}; "
                    "re-initialized SASG worker state from restored params"
                )
            self.log(f"[trainer] restored checkpoint at step {step}")
            return state, step
        return template, 0

    # -- subclass hooks (ElasticTrainer) -----------------------------------

    def _pre_step(self, state: TrainState, step: int) -> TrainState:
        """Before the batch fetch; may raise (node failure) or swap
        ``self.built`` + remap ``state`` (membership resize)."""
        if self.fault_hook is not None:
            self.fault_hook(step)  # legacy hook; may raise
        return state

    def _fetch_batch(self, step: int) -> dict:
        """The batch for training step ``step``. Replayable sources are
        indexed directly (pure in ``step``); plain iterators are consumed."""
        if hasattr(self.data, "batch_at"):
            return self.data.batch_at(step)
        return next(self.data)

    def _force_skip(self, step: int):
        """(M,) bool straggler mask for this step, or None (no stragglers)."""
        return None

    def _seek(self, step: int, initial: bool = False):
        if hasattr(self.data, "seek"):
            self.data.seek(step)
        elif initial and step == 0:
            pass  # a fresh iterator at a fresh start: nothing to rewind
        elif not self._warned_unseekable:
            self._warned_unseekable = True
            self.log(
                "[trainer] WARNING: data source is not seekable; batches "
                "between the restored checkpoint and the failure are lost "
                "(use repro.data.ReplayableStream for exact replay)"
            )

    def _recover(self) -> tuple[TrainState, int]:
        # satellite fix: the restore template must use the caller's init key
        # — a fresh-start recovery with PRNGKey(0) would silently change the
        # run's initialization
        template = self.built.init(self._init_key)
        state, step = self._restore_latest(template)
        self._seek(step)
        return state, step

    # -- main loop ----------------------------------------------------------

    def run(self, init_key=None, state: Optional[TrainState] = None) -> TrainState:
        c = self.cfg
        self._init_key = init_key if init_key is not None else jax.random.PRNGKey(0)
        if state is None:
            state = self.built.init(self._init_key)
        state, start = self._restore_latest(state)
        self._seek(start, initial=True)

        step = start
        restarts = 0
        while step < c.total_steps:
            try:
                state = self._pre_step(state, step)
                batch = self._fetch_batch(step)
                fs = self._force_skip(step)
                if fs is None:
                    state, mets = self.built.jit_step(state, batch)
                else:
                    state, mets = self.built.jit_step(state, batch, fs)
                if step % c.log_every == 0 or step == c.total_steps - 1:
                    loss = float(mets["loss"])
                    sent = float(mets["num_sent"])
                    self.log(
                        f"[trainer] step {step:5d} loss {loss:8.4f} "
                        f"sent {sent:4.0f}/{max(self.built.strategy.num_workers,1)} "
                        f"rounds {float(mets['rounds_total']):9.0f} "
                        f"bits(paper) {float(mets['bits_paper_total']):.3e}"
                    )
                self.history.append({k: float(v) for k, v in mets.items()})
                if c.record_batches:
                    from repro.data.replay import batch_fingerprint

                    self.batch_log.append((step, batch_fingerprint(batch)))
                step += 1
                self._maybe_ckpt(state, step)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # node failure / data failure: recover
                restarts += 1
                if restarts > c.max_restarts:
                    raise
                t0 = time.monotonic()
                self.log(
                    f"[trainer] step {step} failed ({type(e).__name__}: {e}); "
                    f"recovering ({restarts}/{c.max_restarts})"
                )
                self._join_save()  # commit (or mourn) the in-flight save first
                state, new_step = self._recover()
                self.events.append(
                    {
                        "kind": "recovery",
                        "failed_step": step,
                        "restored_step": new_step,
                        "steps_lost": step - new_step,
                        "error": type(e).__name__,
                        "latency_s": time.monotonic() - t0,
                    }
                )
                step = new_step
        self._maybe_ckpt(state, step, force=True)
        self._join_save()
        return state

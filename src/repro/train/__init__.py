from .step import BuiltStep, TrainState, build_train_step
from .loop import Trainer, TrainerConfig
from .elastic import ElasticTrainer, WorkerMembership, fresh_worker_state, remap_state
from .faults import (
    DataStreamError,
    Fault,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    corrupt_checkpoint,
)
from . import checkpoint

from .step import BuiltStep, TrainState, build_train_step
from .loop import Trainer, TrainerConfig
from . import checkpoint

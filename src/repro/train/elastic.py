"""Elastic worker membership: in-run mesh resize without a full restart.

SASG's adaptive aggregation (the LAG/LASG lineage) already tolerates stale
and absent workers, so elasticity here is a scheduling/state-remap problem,
not an algorithm change (DESIGN.md §5). A resize event:

1. builds the new mesh and re-runs ``choose_strategy`` on it (the
   flat/hierarchical/plain decision is re-taken — shrinking below the
   replica-fit threshold can legitimately degrade to "plain");
2. rebuilds the jitted step via ``build_train_step``;
3. carries parameters, optimizer state, global SASG state, comm counters and
   the run RNG **exactly** — ``device_put`` onto the new shardings is pure
   data movement, bit-identical values;
4. remaps SASG worker state: when the membership (worker axes + count) is
   unchanged this is ``core.error_feedback.remap_error_state`` (bit-exact
   resharding, e.g. a stage-count change); when the worker set changed the
   per-worker error-feedback/stale buffers are **re-initialized from the
   carried params** — a residual belongs to a worker that no longer exists,
   and a fresh EF start is exactly the paper's t=0 condition, so convergence
   guarantees keep holding;
5. resumes the data stream at the same step index — with a replayable
   stream (``repro.data.ReplayableStream``) batch ``t`` is identical across
   any resize history.

The same ``fresh_worker_state`` is used by the Trainer's restore path when a
checkpoint's recorded worker count differs from the current strategy's, so
in-run resize and restart-from-checkpoint elasticity land in bit-identical
states (asserted by tests/test_elastic.py).
"""
from __future__ import annotations

from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.error_feedback import remap_error_state, worker_dims_match
from repro.dist.strategy import Strategy, choose_strategy

from .faults import (
    DataStreamError,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    corrupt_checkpoint,
)
from .loop import Trainer, TrainerConfig
from .step import BuiltStep, TrainState, build_train_step


def fresh_worker_state(built: BuiltStep, params: Any) -> Any:
    """Per-worker SASG state initialized from ``params`` (DESIGN.md §5 cold
    start), worker-stacked to the strategy's M and placed on the built
    shardings. Matches ``build_train_step.init_all`` exactly — stale_params
    start at the CURRENT params (not the run's t=0 init), which is the LASG
    t=0 condition relative to the resize point."""
    if not built.strategy.uses_shard_map:
        return ()
    M = built.strategy.num_workers
    ws = built.exchange.init_worker(params)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(
            jnp.asarray(x)[None], (M,) + jnp.asarray(x).shape
        ),
        ws,
    )
    return jax.device_put(stacked, built.state_shardings.wstate)


def remap_state(
    state: TrainState,
    new_built: BuiltStep,
    old_strategy: Optional[Strategy] = None,
) -> TrainState:
    """Carry a TrainState onto a rebuilt step (new mesh/strategy).

    params / opt_state / gstate / counters / rng move bit-exactly
    (device_put onto the new shardings). wstate is carried bit-exactly iff
    the worker membership is unchanged; otherwise re-initialized from the
    carried params (module docstring)."""
    sh = new_built.state_shardings
    params = jax.device_put(state.params, sh.params)
    opt_state = jax.device_put(state.opt_state, sh.opt_state)
    counters = jax.device_put(state.counters, sh.counters)
    rng = jax.device_put(state.rng, sh.rng)

    new_strat = new_built.strategy
    if not new_strat.uses_shard_map:
        return TrainState(params, opt_state, (), (), counters, rng)

    same_membership = (
        old_strategy is not None
        and old_strategy.membership == new_strat.membership
        and worker_dims_match(state.wstate, new_strat.num_workers)
    )
    if same_membership:
        wstate = remap_error_state(state.wstate, sh.wstate)
    else:
        wstate = fresh_worker_state(new_built, params)

    if jax.tree.structure(state.gstate) == jax.tree.structure(
        jax.eval_shape(new_built.exchange.init_global)
    ):
        gstate = jax.device_put(state.gstate, sh.gstate)
    else:  # e.g. plain -> sasg: no global SASG state to carry
        gstate = jax.device_put(new_built.exchange.init_global(), sh.gstate)
    return TrainState(params, opt_state, wstate, gstate, counters, rng)


class WorkerMembership:
    """Factory mapping a worker count to a (mesh, strategy, BuiltStep) and
    remapping state across resizes.

    ``mesh_fn(num_workers)`` builds the post-resize mesh; the default builds
    a 1-D ``("data",)`` mesh over the first ``num_workers`` local devices
    (the CPU test topology). Built steps are cached per worker count —
    growing back to a previous size reuses the compiled step.
    """

    def __init__(
        self,
        model,
        sasg_cfg,
        lr_schedule: Callable,
        optimizer=None,
        mesh_fn: Optional[Callable[[int], Any]] = None,
        **choose_kwargs,
    ):
        self.model = model
        self.sasg_cfg = sasg_cfg
        self.lr_schedule = lr_schedule
        self.optimizer = optimizer
        self.mesh_fn = mesh_fn or self._default_mesh
        self.choose_kwargs = dict(choose_kwargs)
        self._cache: dict = {}

    @staticmethod
    def _default_mesh(num_workers: int):
        devs = jax.devices()
        if num_workers > len(devs):
            raise ValueError(
                f"cannot grow to {num_workers} workers on {len(devs)} devices"
            )
        return compat.make_mesh(
            (num_workers,), ("data",),
            devices=np.array(devs[:num_workers]),
        )

    def build(self, num_workers: int) -> BuiltStep:
        if num_workers in self._cache:
            return self._cache[num_workers]
        mesh = self.mesh_fn(num_workers)
        strategy = choose_strategy(mesh, **self.choose_kwargs)
        built = build_train_step(
            self.model, self.sasg_cfg, mesh, strategy,
            self.lr_schedule, self.optimizer,
        )
        self._cache[num_workers] = built
        return built

    def resize(
        self, state: TrainState, old_built: BuiltStep, num_workers: int
    ) -> tuple[BuiltStep, TrainState]:
        new_built = self.build(num_workers)
        return new_built, remap_state(state, new_built, old_built.strategy)


class ElasticTrainer(Trainer):
    """Trainer with first-class membership events and fault injection.

    ``membership`` enables in-run resizes (worker_drop/worker_join faults
    retarget the worker axis without restarting); ``plan`` schedules faults
    via :class:`~repro.train.faults.FaultInjector`. Per-step fault order is
    fixed and documented: resize -> corrupt_ckpt -> save_fail arming ->
    crash (raise) -> data hiccup (raise, from the batch fetch) ->
    straggler mask (into the step). Everything else — recovery, replayable
    data seek, checkpoint meta — is the base Trainer.
    """

    def __init__(
        self,
        built: BuiltStep,
        data: Iterator[dict],
        cfg: TrainerConfig,
        membership: Optional[WorkerMembership] = None,
        plan: Optional[FaultPlan] = None,
        fault_hook=None,
        log_fn=print,
    ):
        super().__init__(built, data, cfg, fault_hook=fault_hook, log_fn=log_fn)
        self.membership = membership
        self.injector = FaultInjector(plan) if plan is not None else None
        if membership is not None:
            # growing back re-hits this cache (and the ckpt-mismatch restore
            # path builds at the recorded count without a recompile)
            membership._cache.setdefault(built.strategy.num_workers, built)

    # -- fault hooks -------------------------------------------------------

    def _pre_step(self, state: TrainState, step: int) -> TrainState:
        state = super()._pre_step(state, step)
        inj = self.injector
        if inj is None:
            return state

        target = inj.resize_to(step)
        if target is not None and target != self.built.strategy.num_workers:
            if self.membership is None:
                raise RuntimeError(
                    "FaultPlan schedules a membership event but the "
                    "ElasticTrainer has no WorkerMembership"
                )
            old = self.built.strategy.num_workers
            self.built, state = self.membership.resize(state, self.built, target)
            self.log(
                f"[trainer] step {step}: resized worker axis {old} -> "
                f"{target} (strategy {self.built.strategy.name}, state "
                "carried in-run)"
            )
            self.events.append(
                {"kind": "resize", "step": step, "from": old, "to": target}
            )

        cf = inj.corrupt_at(step)
        if cf is not None and self.cfg.ckpt_dir:
            victim = corrupt_checkpoint(self.cfg.ckpt_dir, cf.target_step)
            self.log(f"[trainer] step {step}: corrupted checkpoint step_{victim}")
            self.events.append(
                {"kind": "corrupt_ckpt", "step": step, "victim": victim}
            )

        attempts = inj.save_fail_attempts(step)
        if attempts:
            self._ckpt_fail_attempts = attempts
            self.events.append(
                {"kind": "save_fail_armed", "step": step, "attempts": attempts}
            )

        if inj.crash_at(step):
            self.events.append({"kind": "crash", "step": step})
            raise InjectedFault(f"injected node failure at step {step}")
        return state

    def _fetch_batch(self, step: int) -> dict:
        if self.injector is not None and self.injector.data_hiccup_at(step):
            self.events.append({"kind": "data_hiccup", "step": step})
            raise DataStreamError(f"injected data-stream failure at step {step}")
        return super()._fetch_batch(step)

    def _force_skip(self, step: int):
        if self.injector is None:
            return super()._force_skip(step)
        mask = self.injector.straggler_mask(
            step, self.built.strategy.num_workers
        )
        if mask is not None:
            self.events.append(
                {"kind": "straggler", "step": step,
                 "workers": [int(i) for i in np.flatnonzero(mask)]}
            )
        return mask

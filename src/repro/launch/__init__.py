# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and must
# only be imported as the process entrypoint.
from .mesh import make_production_mesh, make_test_mesh, required_device_count

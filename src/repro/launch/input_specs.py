"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation: everything here is abstract. Shapes are GLOBAL; the
dry-run attaches NamedShardings per the strategy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import NUM_PATCH_TOKENS


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family in ("mlp", "cnn"):
        img = (28, 28, 1) if cfg.family == "mlp" else (32, 32, 3)
        return {
            "x": jax.ShapeDtypeStruct((b,) + img, jnp.float32),
            "labels": jax.ShapeDtypeStruct((b,), i32),
        }
    if cfg.is_encdec:
        ss = s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, ss, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, ss), i32),
            "labels": jax.ShapeDtypeStruct((b, ss), i32),
        }
    if cfg.frontend == "patch_embed":
        np_tok = NUM_PATCH_TOKENS if s > NUM_PATCH_TOKENS else s // 4
        return {
            "tokens": jax.ShapeDtypeStruct((b, s - np_tok), i32),
            "patch_embeds": jax.ShapeDtypeStruct((b, np_tok, cfg.d_model), jnp.float32),
            "labels": jax.ShapeDtypeStruct((b, s - np_tok), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }


def pipeline_microbatch_specs(train_specs: dict, stages: int,
                              microbatches: int = 0, num_workers: int = 1) -> dict:
    """Per-worker microbatched view of a train batch for pipelined dry-runs.

    The train step replicates the worker batch over the ``stage`` axis and
    reshapes it to ``(n_micro, mb, ...)`` inside the shard_map region
    (dist/pipeline.py); these specs describe that region-local shape so the
    dry-run and the roofline can account the GPipe ring traffic.
    """
    from repro.dist.pipeline import resolve_microbatches

    out = {}
    for k, x in train_specs.items():
        b = x.shape[0] // max(num_workers, 1)
        nm = resolve_microbatches(b, microbatches or stages)
        out[k] = jax.ShapeDtypeStruct((nm, b // nm) + x.shape[1:], x.dtype)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, init_cache) -> tuple:
    """(cache_specs, tokens, pos) for one decode step against a seq_len cache."""
    b, s = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(lambda: init_cache(b, s))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    # per-slot positions: the continuous-batching engine decodes every slot
    # at its own offset (-1 freezes a slot), so the lowered unit matches
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    return cache_shape, tokens, pos


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.is_encdec:
        ss = s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, ss, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, ss), i32),
        }
    if cfg.frontend == "patch_embed":
        np_tok = NUM_PATCH_TOKENS
        return {
            "tokens": jax.ShapeDtypeStruct((b, s - np_tok), i32),
            "patch_embeds": jax.ShapeDtypeStruct((b, np_tok, cfg.d_model), jnp.float32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}

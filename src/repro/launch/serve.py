"""Serving driver: continuous-batching engine over a mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch internvl2_2b --reduced \
      --batch 4 --requests 12 --mesh-shape 4,2
"""
import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl2_2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mesh-shape", default="4,2")
    args = ap.parse_args(argv)

    shape = tuple(int(x) for x in args.mesh_shape.split(","))
    ndev = 1
    for s in shape:
        ndev *= s
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ndev} "
        + os.environ.get("XLA_FLAGS", "")
    )

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import build
    from repro.serve import BatchedServer, Request, build_serve

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = make_test_mesh(shape, axes)
    serve = build_serve(model, mesh, fsdp="data", tp="model")
    params = jax.jit(model.init, out_shardings=serve.param_shardings)(
        jax.random.PRNGKey(0)
    )
    srv = BatchedServer(serve, params, cfg, args.batch, args.max_seq)
    rng = np.random.default_rng(0)
    pending = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0, ticks = time.time(), 0
    while pending or any(s is not None for s in srv.slots):
        while pending and srv.submit(pending[0]):
            pending.pop(0)
        srv.tick()
        ticks += 1
    dt = time.time() - t0
    done = len(srv.completed)
    print(f"[serve] {done} requests, {ticks} engine ticks, "
          f"{done * args.max_new / dt:.1f} tok/s (CPU, {ndev} fake devices)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

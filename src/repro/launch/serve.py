"""Serving driver: continuous-batching engine over a mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch internvl2_2b --reduced \
      --batch 4 --requests 12 --mesh-shape 4,2
"""
import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl2_2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mesh-shape", default="4,2")
    ap.add_argument("--dense", action="store_true",
                    help="dense per-slot KV cache (default: paged when the "
                         "arch has global-attention layers)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--cache-dtype", default=None,
                    help="paged-block wire dtype (default: compute dtype, "
                         "bit-exact)")
    args = ap.parse_args(argv)

    shape = tuple(int(x) for x in args.mesh_shape.split(","))
    ndev = 1
    for s in shape:
        ndev *= s
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ndev} "
        + os.environ.get("XLA_FLAGS", "")
    )

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import build
    from repro.serve import BatchedServer, Request, build_serve

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    axes = ("pod", "data", "model")[-len(shape):]
    mesh = make_test_mesh(shape, axes)
    serve = build_serve(model, mesh, fsdp="data", tp="model")
    params = jax.jit(model.init, out_shardings=serve.param_shardings)(
        jax.random.PRNGKey(0)
    )
    paged = False if args.dense else None  # None = auto (paged when pageable)
    srv = BatchedServer(serve, params, cfg, args.batch, args.max_seq,
                        paged=paged, block_size=args.block_size,
                        cache_dtype=args.cache_dtype)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        srv.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.time()
    done, pending = srv.drain(strict=True)
    dt = time.time() - t0
    stats = srv.cache_stats()
    mode = "paged" if srv.paged else "dense"
    print(f"[serve] {len(done)} requests, {stats['ticks']} engine ticks "
          f"({mode} cache, {stats['cache_dtype']}), "
          f"{stats['decode_tokens'] / dt:.1f} tok/s (CPU, {ndev} fake devices)")
    if srv.paged:
        print(f"[serve] block high-water {stats['block_high_water']}"
              f"/{stats['num_blocks']}: {stats['high_water_bytes']:.0f} B "
              f"vs dense-equivalent {stats['dense_equiv_bytes']:.0f} B")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Drive the full dry-run matrix: every (arch x shape x mesh) cell in its own
subprocess (each mesh needs its own --xla_force_host_platform_device_count,
and a crashed partitioner must not take down the sweep).

  PYTHONPATH=src python -m repro.launch.run_all_dryruns \
      [--mesh single multi] [--jobs 2] [--arch ...] [--shape ...]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARCHS = [
    "llama3_8b", "chatglm3_6b", "starcoder2_3b", "granite_20b", "kimi_k2",
    "mixtral_8x7b", "recurrentgemma_9b", "mamba2_370m", "seamless_m4t_v2",
    "internvl2_2b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_one(arch, shape, mesh, out, algo, timeout):
    tag = f"{arch}__{shape}__{mesh}"
    path = os.path.join(out, f"{arch}__{shape}__{mesh}__{algo}.json")
    if os.path.exists(path):
        try:
            rec = json.load(open(path))
            if rec.get("status") in ("ok", "skipped"):
                return tag, rec.get("status"), 0.0, "cached"
        except json.JSONDecodeError:
            pass
    t0 = time.time()
    env = dict(os.environ)
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh,
        "--algo", algo, "--out", out,
    ]
    try:
        p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
        status = "ok" if p.returncode == 0 else "error"
        if os.path.exists(path):
            rec = json.load(open(path))
            status = rec.get("status", status)
        else:
            rec = {"status": status, "reason": (p.stderr or "")[-400:]}
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "algo": algo, **rec}, f, indent=1)
    except subprocess.TimeoutExpired:
        status = "timeout"
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "algo": algo, "status": "timeout"}, f, indent=1)
    return tag, status, time.time() - t0, ""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--arch", nargs="+", default=ARCHS)
    ap.add_argument("--shape", nargs="+", default=SHAPES)
    ap.add_argument("--algo", default="sasg")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = [
        (a, s, m) for m in args.mesh for a in args.arch for s in args.shape
    ]
    print(f"{len(cells)} cells, {args.jobs} parallel jobs")
    results = []
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = [
            ex.submit(run_one, a, s, m, args.out, args.algo, args.timeout)
            for a, s, m in cells
        ]
        for f in futs:
            tag, status, dt, note = f.result()
            print(f"  {tag:55s} {status:8s} {dt:7.1f}s {note}", flush=True)
            results.append((tag, status))
    bad = [t for t, s in results if s not in ("ok", "skipped")]
    print(f"done: {len(results) - len(bad)}/{len(results)} ok; failures: {bad}")


if __name__ == "__main__":
    main()

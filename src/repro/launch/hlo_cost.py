"""While-loop-aware HLO cost analyzer.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE,
but scan-over-layers turns the entire model into a while body — so flops,
bytes, and collective traffic are undercounted by ~L x (measured 13x on
llama3-8b train_4k). This module parses the optimized per-device HLO text,
recovers loop trip counts from the loop-condition constants, and aggregates

  - dot FLOPs (2 * prod(result dims) * contracted dim),
  - an HBM-traffic proxy (operand + result bytes of top-level fusions/ops),
  - collective result/wire bytes (ring-model factors per replica-group size)

with every instruction weighted by the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}\d]+))\s+([\w\-]+)\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
}


def _shape_list(s: str):
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, shape in _shape_list(s):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclass
class Inst:
    name: str
    opcode: str
    result_shapes: str
    line: str
    callees: list


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_result: Dict[str, float] = field(default_factory=dict)
    coll_wire: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CompCost", w: float = 1.0):
        self.flops += w * other.flops
        self.bytes += w * other.bytes
        for k, v in other.coll_result.items():
            self.coll_result[k] = self.coll_result.get(k, 0.0) + w * v
        for k, v in other.coll_wire.items():
            self.coll_wire[k] = self.coll_wire.get(k, 0.0) + w * v


def parse_computations(hlo: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m and line.endswith("{") and "->" in line:
            cur = m.group(1)
            comps[cur] = []
            if raw.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INST_RE.match(line)
        if mi:
            name, shapes, opcode = mi.group(1), mi.group(2), mi.group(3)
            callees = _CALL_RE.findall(line)
            comps[cur].append(Inst(name, opcode, shapes, line, callees))
    comps["__entry__"] = comps.get(entry, [])
    return comps


def build_shape_map(comps: Dict[str, list]) -> Dict[str, tuple]:
    """name -> result shape (first shape in the def line), across all comps.
    Parameter shapes come from their own def lines (`%p = f32[..] parameter`)."""
    out: Dict[str, tuple] = {}
    for insts in comps.values():
        if not isinstance(insts, list):
            continue
        for inst in insts:
            sl = _shape_list(inst.result_shapes)
            if sl:
                out[inst.name] = sl[0][1]
    return out


def _dot_flops(line: str, result_shapes: str, shape_of: Dict[str, tuple]) -> float:
    """2 * prod(result) * contracted-size. Operand shapes are not printed
    inline in CPU HLO, so the lhs shape is resolved via the global
    name -> shape map built during parsing."""
    shapes = _shape_list(result_shapes)
    if not shapes:
        return 0.0
    _, rshape = shapes[0]
    rsize = 1
    for d in rshape:
        rsize *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    lhs_shape = None
    paren = line.split(" dot(", 1)
    if len(paren) == 2:
        ops = _OPERAND_RE.findall(paren[1].split(")", 1)[0])
        if ops:
            lhs_shape = shape_of.get(ops[0])
    csize = 1
    if m and lhs_shape:
        for d in m.group(1).split(","):
            if d:
                di = int(d)
                if di < len(lhs_shape):
                    csize *= lhs_shape[di]
    return 2.0 * rsize * csize


def _trip_count(cond_insts: list) -> int:
    """Loop trip count from the condition computation: the bound appears as
    an s32 constant feeding the (possibly fusion-wrapped) compare — take the
    largest positive integer constant in the condition."""
    best = 1
    for inst in cond_insts:
        if inst.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", inst.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def analyze(hlo: str) -> CompCost:
    comps = parse_computations(hlo)
    shape_of = build_shape_map(comps)
    memo: Dict[str, CompCost] = {}

    def cost_of(comp_name: str, stack=()) -> CompCost:
        if comp_name in memo:
            return memo[comp_name]
        if comp_name in stack or comp_name not in comps:
            return CompCost()
        total = CompCost()
        for inst in comps[comp_name]:
            op = inst.opcode
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", inst.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", inst.line)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    total.add(cost_of(body, stack + (comp_name,)), float(trips))
                continue
            if op in ("call", "conditional"):
                for c in inst.callees:
                    total.add(cost_of(c, stack + (comp_name,)))
            elif op == "fusion":
                # fusions internalize intermediates (we charge the fusion's
                # result bytes below) but dots inside them are real compute
                for c in inst.callees:
                    sub = cost_of(c, stack + (comp_name,))
                    total.flops += sub.flops
            base = op.replace("-start", "")
            if base in _COLLECTIVES or base in (
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute",
            ):
                rb = _shape_bytes(inst.result_shapes)
                g = max(_group_size(inst.line), 1)
                if base == "all-reduce":
                    wire = 2.0 * (g - 1) / g * rb
                elif base == "all-gather":
                    wire = (g - 1) / g * rb
                elif base == "reduce-scatter":
                    wire = (g - 1) * rb
                elif base == "all-to-all":
                    wire = (g - 1) / g * rb
                else:
                    wire = rb
                total.coll_result[base] = total.coll_result.get(base, 0.0) + rb
                total.coll_wire[base] = total.coll_wire.get(base, 0.0) + wire
            if op == "dot":
                total.flops += _dot_flops(inst.line, inst.result_shapes, shape_of)
            if op == "convolution":
                # approximate: 2 * result * (kernel spatial x in-channels)
                shapes = _shape_list(inst.line)
                if len(shapes) >= 3:
                    rsize = 1
                    for d in shapes[0][1]:
                        rsize *= d
                    ksz = 1
                    for d in shapes[2][1]:
                        ksz *= d
                    out_c = shapes[0][1][-1] if shapes[0][1] else 1
                    total.flops += 2.0 * rsize * (ksz / max(out_c, 1))
            # HBM proxy: charge result bytes once per top-level instruction
            # (operands were produced and charged at their def site). Skip
            # pure control/aliasing ops.
            if op not in ("parameter", "constant", "tuple", "get-tuple-element",
                          "bitcast", "while", "call", "conditional"):
                total.bytes += _shape_bytes(inst.result_shapes)
        memo[comp_name] = total
        return total

    return cost_of("__entry__")

"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --reduced \
      --algo sasg --steps 200 --mesh-shape 2,4 --ckpt-dir /tmp/ckpt

On the single-CPU container use --fake-devices N to build a small mesh; on a
real cluster jax.distributed.initialize() picks up the pod topology and the
production mesh from launch/mesh.py applies.
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--algo", default="sasg",
                    choices=["sgd", "sparse", "lasg", "sasg"])
    ap.add_argument("--k-ratio", type=float, default=0.01)
    ap.add_argument("--compressor", default=None,
                    help="override the preset's compressor (topk_ef, randk, "
                         "qsgd, signsgd_ef, terngrad, identity) — every "
                         "compressor composes with --stages via the "
                         "repro.comm transport")
    ap.add_argument("--topk-impl", default=None,
                    help="topk_ef impl: kernel (fused Pallas, default) | "
                         "reference | exact")
    ap.add_argument("--layout", default=None,
                    help="wire layout: per_shard | per_tensor | flat")
    ap.add_argument("--wire-dtype", default=None,
                    help="payload value dtype on the wire (e.g. bfloat16)")
    ap.add_argument("--k-ratio-per-layer", default=None,
                    help="layer-wise k schedule: 'pattern=ratio,...' matched "
                         "against leaf paths (Shi et al., 2019)")
    ap.add_argument("--max-delay", type=int, default=10)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--mesh-shape", default="4,2",
                    help="data,model (or pod,data,model) sizes")
    ap.add_argument("--stages", type=int, default=1,
                    help="GPipe pipeline stages; >1 inserts a stage axis of "
                         "that size before the LAST --mesh-shape entry (the "
                         "model axis — keep the data axis in --mesh-shape, "
                         "e.g. --mesh-shape 2,1 --stages 2)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="GPipe microbatches per worker (0 -> stages)")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--resize", default=None,
                    help="in-run elastic membership events: 'STEP:WORKERS,"
                         "STEP:WORKERS,...' (e.g. '50:2,100:4' shrinks the "
                         "worker axis to 2 at step 50, grows back to 4 at "
                         "100 — no restart, state carried per DESIGN.md §5)")
    ap.add_argument("--faults", default=None,
                    help="chaos injection: 'KIND@STEP,...' with KIND in "
                         "crash, straggler, corrupt_ckpt, save_fail, "
                         "data_hiccup (e.g. 'crash@30,data_hiccup@70')")
    args = ap.parse_args(argv)

    shape = tuple(int(x) for x in args.mesh_shape.split(","))
    if args.stages > 1:
        shape = shape[:-1] + (args.stages, shape[-1])
    ndev = 1
    for s in shape:
        ndev *= s
    if args.fake_devices or ndev > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={max(args.fake_devices, ndev)} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from repro.configs import get_config
    from repro.core import PRESETS
    from repro.data import (
        indexed_classification_stream,
        indexed_token_stream,
        synthetic_classification,
    )
    from repro.dist.strategy import choose_strategy
    from repro.launch.mesh import make_test_mesh
    from repro.models import build
    from repro.optim import constant
    from repro.train import (
        ElasticTrainer,
        Fault,
        FaultPlan,
        Trainer,
        TrainerConfig,
        WorkerMembership,
        build_train_step,
    )
    from repro.core.types import tree_bytes

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg, remat=args.remat)

    if args.stages > 1:
        axes = ("pod", "data", "stage", "model")[-len(shape):]
    else:
        axes = ("pod", "data", "model")[-len(shape):]
    mesh = make_test_mesh(shape, axes)
    params_bytes = tree_bytes(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    strategy = choose_strategy(
        mesh, sasg_enabled=args.algo != "sgd", params_bytes=params_bytes,
        pipeline_stages=args.stages, microbatches=args.microbatches,
        trunk_layers=model.pipeline.n_layers if model.pipeline else 0,
    )
    print(f"[train] arch={cfg.name} algo={args.algo} mesh={dict(zip(axes, shape))} "
          f"strategy={strategy.name} workers={strategy.num_workers} "
          f"stages={strategy.pipeline_stages}")

    if args.algo in ("sasg", "sparse"):
        scfg = PRESETS[args.algo](k_ratio=args.k_ratio)
    else:
        scfg = PRESETS[args.algo]()
    comp_overrides = {}
    if args.compressor:
        comp_overrides["name"] = args.compressor
    if args.topk_impl:
        comp_overrides["topk_impl"] = args.topk_impl
    if args.layout:
        comp_overrides["layout"] = args.layout
    if args.wire_dtype:
        comp_overrides["wire_dtype"] = args.wire_dtype
    if args.k_ratio_per_layer:
        schedule = []
        for item in args.k_ratio_per_layer.split(","):
            pattern, sep, ratio = item.partition("=")
            if not sep or not pattern:
                ap.error(f"--k-ratio-per-layer entry {item!r} is not "
                         "'pattern=ratio'")
            try:
                schedule.append((pattern, float(ratio)))
            except ValueError:
                ap.error(f"--k-ratio-per-layer ratio {ratio!r} is not a float")
        comp_overrides["k_ratio_per_layer"] = tuple(schedule)
    if comp_overrides:
        import dataclasses

        scfg = dataclasses.replace(
            scfg, compressor=dataclasses.replace(scfg.compressor, **comp_overrides)
        )
    built = build_train_step(model, scfg, mesh, strategy, constant(args.lr))
    if built.exchange is not None:
        t = built.exchange.transport
        print(f"[train] transport kind={t.kind} layout={t.layout} "
              f"bits/upload paper={built.bits_paper:.3e} "
              f"wire={built.bits_wire:.3e}")

    # replayable (step-indexed) streams: batch t is a pure function of
    # (seed, t), so recovery and elastic resizes replay the exact batch
    # sequence an uninterrupted run would consume (DESIGN.md §5)
    if cfg.family in ("mlp", "cnn"):
        # paper nets train on the synthetic classification mixture, not tokens
        img = (28, 28, 1) if cfg.family == "mlp" else (32, 32, 3)
        xs, ys = synthetic_classification(2048, cfg.vocab_size, img, seed=0)
        stream = indexed_classification_stream(xs, ys, args.global_batch, seed=0)
    else:
        stream = indexed_token_stream(
            cfg.vocab_size, args.global_batch, args.seq_len, seed=0
        )

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        log_every=max(args.steps // 20, 1),
    )
    plan = None
    if args.resize or args.faults:
        plan = FaultPlan()
        for item in (args.resize or "").split(",") if args.resize else ():
            step_s, sep, workers_s = item.partition(":")
            if not sep:
                ap.error(f"--resize entry {item!r} is not 'STEP:WORKERS'")
            step_i, target = int(step_s), int(workers_s)
            cur = strategy.num_workers
            plan = (plan.worker_drop(step_i, to=target) if target < cur
                    else plan.worker_join(step_i, to=target))
        for item in (args.faults or "").split(",") if args.faults else ():
            kind, sep, step_s = item.partition("@")
            if not sep:
                ap.error(f"--faults entry {item!r} is not 'KIND@STEP'")
            try:
                plan = plan._with(Fault(kind, int(step_s)))
            except ValueError as e:
                ap.error(str(e))
    if plan is not None:
        def resized_mesh(n):
            # keep the non-worker axes (model/stage) and retarget only the
            # worker axis size; fake devices cap how far we can grow
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            wa = strategy.worker_axes[0] if strategy.worker_axes else "data"
            sizes[wa] = n
            return make_test_mesh(tuple(sizes.values()), tuple(sizes.keys()))

        membership = WorkerMembership(
            model, scfg, constant(args.lr), mesh_fn=resized_mesh,
            sasg_enabled=args.algo != "sgd", params_bytes=params_bytes,
        )
        trainer = ElasticTrainer(built, stream, tcfg,
                                 membership=membership, plan=plan)
    else:
        trainer = Trainer(built, stream, tcfg)
    state = trainer.run(init_key=jax.random.PRNGKey(0))
    print(f"[train] done: {args.steps} steps; total rounds "
          f"{float(state.counters.rounds):.0f}; bits(paper) "
          f"{float(state.counters.bits_paper):.3e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

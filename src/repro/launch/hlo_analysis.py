"""Post-compile HLO analysis: collective bytes + roofline terms (§Roofline).

cost_analysis() gives per-device FLOPs and HBM bytes but NOT collective
traffic, so collective bytes are parsed from the optimized (SPMD-partitioned,
per-device) HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction's shapes, scaled by the standard
ring-algorithm wire factors per group size.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict

from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_LIST_ALL_RE = re.compile(r"replica_groups=\{(\{[\d,]+\}(?:,\{[\d,]+\})*)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{\d+,\d+\}(?:,\{\d+,\d+\})*)\}")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_replica_groups(line: str):
    """Device-id groups of a collective line, or None when absent.

    Handles both HLO spellings:
    - explicit list:  ``replica_groups={{0,2},{1,3}}``
    - iota form:      ``replica_groups=[2,2]<=[4]`` (optionally with a
      transpose, ``[2,2]<=[2,2]T(1,0)``): iota over prod(dims), reshaped to
      ``dims``, transposed by the permutation, flattened, then regrouped as
      ``[num_groups, group_size]``.
    """
    m = _GROUPS_LIST_ALL_RE.search(line)
    if m:
        return [
            [int(d) for d in grp.split(",")]
            for grp in m.group(1)[1:-1].split("},{")
        ]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = list(range(math.prod(dims)))
        if m.group(4):
            import numpy as _np

            perm = [int(p) for p in m.group(4).split(",")]
            ids = list(_np.arange(len(ids)).reshape(dims).transpose(perm).reshape(-1))
        return [
            [int(i) for i in ids[g * gsize:(g + 1) * gsize]]
            for g in range(ngroups)
        ]
    return None


def parse_source_target_pairs(line: str):
    """collective-permute ``source_target_pairs`` as [(src, tgt), ...]."""
    m = _PAIRS_RE.search(line)
    if not m:
        return None
    return [
        tuple(int(d) for d in pair.split(","))
        for pair in m.group(1)[1:-1].split("},{")
    ]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def wire_factor(kind: str, group_size: int) -> float:
    """Ring-algorithm per-device wire bytes as a multiple of the RESULT
    bytes, per replica-group size g (one factor table shared by the flat
    parser, the loop-aware analyzer, and the collective auditor)."""
    g = max(group_size, 1)
    if kind == "all-reduce":
        # ring all-reduce: 2*(g-1)/g * payload per device
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        # result holds g shards; each device receives (g-1)/g of result
        return (g - 1) / g
    if kind == "reduce-scatter":
        # result is the local shard; sends (g-1) shard-sized messages
        return float(g - 1)
    if kind == "all-to-all":
        return (g - 1) / g
    # collective-permute: one send+recv of the payload
    return 1.0


@dataclass
class CollectiveStats:
    # per-device bytes by op kind: 'result' = result-shape bytes,
    # 'wire' = ring-model bytes actually crossing links per device
    result_bytes: Dict[str, float] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)
    top: list = field(default_factory=list)  # (bytes, kind, shapes, op_name)

    @property
    def total_wire(self) -> float:
        return sum(self.wire_bytes.values())

    def top_list(self, n: int = 12) -> list:
        return sorted(self.top, reverse=True)[:n]


_OPNAME_RE = re.compile(r'op_name="([^"]{0,120})')


def collect_collectives(hlo_text: str, top_n: int = 12) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        rb = _shape_bytes(shapes)
        g = max(_group_size(line), 1)
        nm = _OPNAME_RE.search(line)
        stats.top.append(
            (rb, kind, shapes[:80], nm.group(1) if nm else "")
        )
        wire = wire_factor(kind, g) * rb
        stats.result_bytes[kind] = stats.result_bytes.get(kind, 0.0) + rb
        stats.wire_bytes[kind] = stats.wire_bytes.get(kind, 0.0) + wire
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
    return stats


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    wire_bytes_per_device: float,
) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS_BF16
    memory_s = hbm_bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW_PER_LINK
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    step_s = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_time_bound_s": step_s,
        # fraction of roofline: useful-compute time / bound (set by caller
        # against MODEL_FLOPS)
    }


def active_param_count(params_shape, moe_cfg=None) -> tuple:
    """(total_params, active_params): active scales expert leaves by top_k/E
    (plus shared experts, which are always active)."""
    import jax

    total = 0
    active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if moe_cfg is not None and re.search(r"experts_", path):
            active += n * (moe_cfg.top_k / moe_cfg.num_experts)
        else:
            active += n
    return total, active


def model_flops(active_params: float, tokens: float, kind: str) -> float:
    """6*N*D for training, 2*N*D for inference-forward (global, all chips)."""
    return (6.0 if kind == "train" else 2.0) * active_params * tokens

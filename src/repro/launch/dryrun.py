import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell against the production mesh, and
record memory / cost / collective analysis for the roofline (deliverable g).

Run one cell:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b \
      --shape train_4k --mesh single --algo sasg --out artifacts/dryrun

Run everything (drives one subprocess per cell; see launch/run_all_dryruns.py):
  PYTHONPATH=src python -m repro.launch.run_all_dryruns
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str, algo: str = "sasg",
             remat: str = "dots", k_ratio: float = 0.01, out_dir: str = None,
             extra_tag: str = "", ssm_chunk: int = 0) -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, cell_applicable, get_config
    from repro.core import PRESETS
    from repro.core.types import tree_bytes
    from repro.dist.strategy import choose_strategy
    from repro.launch import hlo_analysis as H
    from repro.launch.input_specs import (
        decode_specs,
        prefill_batch_specs,
        train_batch_specs,
    )
    from repro.launch.mesh import HBM_PER_CHIP, make_production_mesh
    from repro.models import build
    from repro.optim import constant
    from repro.serve import build_serve
    from repro.train import build_train_step

    t0 = time.time()
    cfg = get_config(arch)
    if ssm_chunk and cfg.ssm is not None:
        from dataclasses import replace as _replace

        cfg = _replace(cfg, ssm=_replace(cfg.ssm, chunk_size=ssm_chunk))
    shp = SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "algo": algo,
        "kind": shp.kind, "remat": remat, "tag": extra_tag,
    }

    ok, reason = cell_applicable(arch, shape_name)
    if not ok:
        record.update(status="skipped", reason=reason)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            tag = f"_{extra_tag}" if extra_tag else ""
            fname = f"{arch}__{shape_name}__{mesh_kind}__{algo}{tag}.json"
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(record, f, indent=1)
        return record

    model = build(cfg, remat=remat)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pbytes = tree_bytes(params_shape)
    total_p, active_p = H.active_param_count(params_shape, cfg.moe)
    record.update(params=total_p, active_params=active_p, params_bytes=pbytes)

    # Train cells with a pipeline preference get the stage axis carved out of
    # the data axis; serve cells never pipeline. Pre-check the knob so an
    # infeasible preference (no trunk, indivisible trunk or data axis) never
    # cripples the mesh — and if choose_strategy itself falls back (e.g. the
    # params_bytes fit check lands on "plain"), rebuild the uncarved mesh so
    # the recorded layout matches the real non-pipelined production run.
    trunk = model.pipeline.n_layers if model.pipeline else 0
    stages = cfg.pipeline_stages if shp.kind == "train" else 1
    data_axis = 16  # make_production_mesh data-axis size (both mesh kinds)
    if stages > 1 and (trunk <= 0 or trunk % stages or data_axis % stages):
        stages = 1
    mesh = make_production_mesh(multi_pod=multi_pod, pipeline_stages=stages)

    if shp.kind == "train":
        strategy = choose_strategy(
            mesh, sasg_enabled=algo != "sgd", params_bytes=pbytes,
            pipeline_stages=stages, trunk_layers=trunk,
        )
        if stages > 1 and not strategy.pipelined:
            mesh = make_production_mesh(multi_pod=multi_pod)
            strategy = choose_strategy(
                mesh, sasg_enabled=algo != "sgd", params_bytes=pbytes,
            )
        record["strategy"] = strategy.name
        record["pipeline_stages"] = strategy.pipeline_stages
    chips = int(mesh.devices.size)
    record["chips"] = chips

    if shp.kind == "train":
        if algo == "sasg_opt":
            # beyond-paper optimized variant (EXPERIMENTS.md §Perf iters 4-5):
            # probe-based selection + compact bf16 wire payloads on the
            # per-shard fused-kernel transport (Pallas topk_ef on TPU)
            from repro.core import CompressorConfig, SASGConfig, SelectionConfig

            scfg = SASGConfig(
                compressor=CompressorConfig(
                    name="topk_ef", k_ratio=k_ratio,
                    layout="per_shard", topk_impl="kernel",
                    wire_dtype="bfloat16", compact_indices=True,
                ),
                selection=SelectionConfig(
                    enabled=True, max_delay=10, probe_fraction=0.125
                ),
                name="sasg_opt",
            )
        elif algo in ("sasg", "sparse"):
            scfg = PRESETS[algo](k_ratio=k_ratio)
        else:
            scfg = PRESETS[algo]()
        built = build_train_step(model, scfg, mesh, strategy, constant(1e-2))
        if built.exchange is not None:
            record["transport"] = {
                "kind": built.exchange.transport.kind,
                "layout": built.exchange.transport.layout,
                "bits_paper_per_upload": built.bits_paper,
                "bits_wire_per_upload": built.bits_wire,
            }
        state_shape = jax.eval_shape(built.init, jax.random.PRNGKey(0))
        state_sds = jax.tree.map(
            lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh),
            state_shape, built.state_shardings,
        )
        batch = train_batch_specs(cfg, shp)
        if strategy.pipelined:
            from repro.launch.input_specs import pipeline_microbatch_specs

            record["pipeline_microbatch"] = {
                k: list(v.shape)
                for k, v in pipeline_microbatch_specs(
                    batch, strategy.pipeline_stages, strategy.microbatches,
                    strategy.num_workers,
                ).items()
            }
        bshard = built.batch_sharding_fn(batch)
        batch_sds = jax.tree.map(
            lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh),
            batch, bshard,
        )
        lowered = jax.jit(built.step, donate_argnums=(0,)).lower(state_sds, batch_sds)
        tokens = shp.global_batch * shp.seq_len
        flops_kind = "train"
    else:
        serve = build_serve(model, mesh, fsdp="data", tp="model")
        pspecs = serve.param_shardings
        params_sds = jax.tree.map(
            lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh),
            params_shape, pspecs,
        )
        record["strategy"] = "serve(fsdp=data,tp=model)"
        if shp.kind == "decode":
            cache_shape, tok_sds, pos_sds = decode_specs(cfg, shp, model.init_cache)
            cshard = serve.cache_sharding_fn(cache_shape)
            cache_sds = jax.tree.map(
                lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh),
                cache_shape, cshard,
            )
            tok_sds = jax.ShapeDtypeStruct(
                tok_sds.shape, tok_sds.dtype,
                sharding=NamedSharding(mesh, P(
                    "data" if tok_sds.shape[0] % mesh.shape["data"] == 0 else None,
                    None)),
            )
            lowered = jax.jit(serve.decode_step, donate_argnums=(1,)).lower(
                params_sds, cache_sds, tok_sds, pos_sds
            )
            tokens = shp.global_batch * 1
        else:  # prefill
            batch = prefill_batch_specs(cfg, shp)
            dp = "data"
            batch_sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    x.shape, x.dtype,
                    sharding=NamedSharding(mesh, P(
                        dp if x.shape[0] % mesh.shape[dp] == 0 else None,
                        *([None] * (len(x.shape) - 1)))),
                ),
                batch,
            )
            lowered = jax.jit(model.prefill).lower(params_sds, batch_sds)
            tokens = shp.global_batch * shp.seq_len
        flops_kind = "serve"

    t_lower = time.time()
    record["lower_s"] = t_lower - t0
    compiled = lowered.compile()
    record["compile_s"] = time.time() - t_lower

    ca = compiled.cost_analysis() or {}
    record["xla_cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "note": "XLA counts while bodies once; roofline uses the loop-aware analyzer",
    }
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes"):
            mem[f] = int(getattr(ma, f, 0))
        live = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"] \
            + mem["output_size_in_bytes"] - mem["alias_size_in_bytes"]
        mem["peak_live_bytes_est"] = live
        mem["fits_16g_hbm"] = bool(live <= HBM_PER_CHIP)
    record["memory"] = mem

    hlo = compiled.as_text()
    from repro.launch import hlo_cost as HC

    cost = HC.analyze(hlo)                      # while-loop trip-count aware
    flops = cost.flops
    # bytes proxy: every top-level buffer written once and read ~once
    bytes_acc = 2.0 * cost.bytes
    colls_flat = H.collect_collectives(hlo)     # un-scaled, for top-list attribution
    record["collectives"] = {
        "counts": colls_flat.counts,
        "result_bytes": cost.coll_result,
        "wire_bytes": cost.coll_wire,
        "total_wire_bytes": sum(cost.coll_wire.values()),
        "top_unscaled": [
            {"bytes": b, "kind": k, "shape": s, "op": o}
            for b, k, s, o in colls_flat.top_list(12)
        ],
    }

    terms = H.roofline_terms(flops, bytes_acc, sum(cost.coll_wire.values()))
    mf = H.model_flops(active_p, tokens, "train" if flops_kind == "train" else "serve")
    mf_per_dev = mf / chips
    terms["model_flops_global"] = mf
    terms["model_flops_per_device"] = mf_per_dev
    terms["hlo_flops_per_device"] = flops
    terms["hlo_bytes_per_device"] = bytes_acc
    terms["useful_flops_ratio"] = (mf_per_dev / flops) if flops else 0.0
    terms["roofline_fraction"] = (
        (mf_per_dev / 197e12) / terms["step_time_bound_s"]
        if terms["step_time_bound_s"] else 0.0
    )
    record["roofline"] = terms
    record["status"] = "ok"
    record["total_s"] = time.time() - t0

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"_{extra_tag}" if extra_tag else ""
        fname = f"{arch}__{shape_name}__{mesh_kind}__{algo}{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--algo", default="sasg")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--k-ratio", type=float, default=0.01)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    args = ap.parse_args()
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.algo, args.remat,
                       args.k_ratio, args.out, args.tag, args.ssm_chunk)
        status = rec.get("status")
        print(json.dumps(rec, indent=1))
        if status == "ok":
            print(f"DRYRUN OK {args.arch} {args.shape} {args.mesh}", file=sys.stderr)
        else:
            print(f"DRYRUN {status}: {rec.get('reason','')}", file=sys.stderr)
        sys.exit(0)
    except Exception:
        traceback.print_exc()
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "algo": args.algo, "status": "error",
            "reason": traceback.format_exc(limit=4),
        }
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"_{args.tag}" if args.tag else ""
            fname = f"{args.arch}__{args.shape}__{args.mesh}__{args.algo}{tag}.json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(rec, f, indent=1)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=<n> BEFORE importing jax.
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False, pipeline_stages: int = 1):
    """The production device mesh. ``pipeline_stages >= 2`` carves a
    ``stage`` axis out of the data axis (stages are contiguous device blocks
    inside what would otherwise be data slices, keeping the high-traffic
    model axis innermost); the data-axis size must divide evenly."""
    import numpy as np

    import jax

    from repro import compat

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pipeline_stages > 1:
        di = axes.index("data")
        if shape[di] % pipeline_stages:
            raise ValueError(
                f"data axis {shape[di]} not divisible by "
                f"pipeline_stages={pipeline_stages}"
            )
        shape = (shape[:di] + (shape[di] // pipeline_stages, pipeline_stages)
                 + shape[di + 1:])
        axes = axes[:di + 1] + ("stage",) + axes[di + 1:]
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before importing jax"
        )
    return compat.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    from repro import compat

    return compat.make_mesh(shape, axes)


def required_device_count(multi_pod: bool) -> int:
    return 512 if multi_pod else 256


# TPU v5e hardware constants used by the roofline analysis (§Roofline)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW_PER_LINK = 50e9         # bytes/s per link (we report per-link terms)
HBM_PER_CHIP = 16 * 2 ** 30

"""GPipe-style microbatch pipeline parallelism over a mesh stage axis.

The layer stack is split into S contiguous stages along a (manual) mesh
axis; microbatches stream through the stages with activations handed to the
next stage by a ring ``ppermute`` each tick. After ``n_micro + S - 1`` ticks
every microbatch has traversed every stage; the last stage's outputs are
psum-broadcast so the result is replicated over the stage axis, numerically
identical to applying all ``S * layers_per_stage`` layers sequentially —
forward and backward both, covered by ``tests/test_pipeline.py`` (2- and
4-stage, values and grads).

Composition with the SASG exchange (strategy -> sharding -> pipeline ->
step): ``train/step.py`` places the stage axis in the shard_map manual set
next to the worker axes, hands each stage its slice of the model's
stage-stacked trunk params (``dist.sharding.param_specs`` with
``stage_axis``/``trunk_paths``), and swaps the exchange's ``grad_fn`` for
``build_pipelined_vag`` — so the fresh gradient AND the stale-params
auxiliary gradient of the LASG rule (paper eq. 6/7) run through the same
pipelined forward/backward, preserving the same-minibatch variance
cancellation. The returned gradient is the FULL tree replicated over the
stage axis (trunk all-gathered, the rest psum-combined via the stage-0 loss
mask), so the selection rule, error feedback, top-k compression, and the
worker-axis exchange are bit-identical to the non-pipelined step
(``tests/test_pipeline_sasg.py``). Auto TP axes compose transparently, as
in the worker exchange.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

# submodule import (not the repro.comm package __init__) to avoid import
# cycles; all stage-axis collectives below go through the repro.comm seam
from repro.comm import collectives as comm_collectives


def build_pipelined_forward(layer_fn: Callable, layers_per_stage: int,
                            axis: str = "stage") -> Callable:
    """Fold ``layers_per_stage`` applications of ``layer_fn`` into one stage.

    ``layer_fn(w, h) -> h`` consumes one layer's params; the returned
    ``stage_fn(wseg, h)`` consumes the stage's params stacked on a leading
    ``layers_per_stage`` dim (array or pytree of arrays). ``axis`` names the
    stage axis for documentation/symmetry with ``pipeline_apply``.
    """

    def stage_fn(wseg, h):
        for l in range(layers_per_stage):
            h = layer_fn(jax.tree.map(lambda w: w[l], wseg), h)
        return h

    return stage_fn


def pipeline_apply(stage_fn: Callable, wseg, micro_x: jax.Array,
                   axis: str = "stage") -> jax.Array:
    """Run microbatches through the stage pipeline. Call inside shard_map.

    ``wseg`` is this stage's params (stage-stacked dim already stripped);
    ``micro_x`` is the full (n_micro, mb, ...) microbatch array, replicated
    over the stage axis. Returns the fully-processed (n_micro, mb, ...)
    outputs, replicated over the stage axis.
    """
    n_micro = micro_x.shape[0]
    S = jax.lax.psum(1, axis)        # static axis size (concrete-operand psum)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % S) for i in range(S)]
    first = idx == 0
    last = idx == S - 1

    carry = jnp.zeros_like(micro_x[0])
    out = jnp.zeros_like(micro_x)
    for t in range(n_micro + S - 1):
        # stage 0 feeds fresh microbatches (re-feeding the final one during
        # drain ticks — those results never land in ``out``); later stages
        # consume what the ring delivered last tick.
        x_in = jnp.where(first, micro_x[min(t, n_micro - 1)], carry)
        y = stage_fn(wseg, x_in)
        done = t - (S - 1)           # microbatch completing at this tick
        if 0 <= done < n_micro:
            out = out.at[done].set(y)
        carry = jax.lax.ppermute(y, axis, perm)

    # only the last stage holds finished microbatches; psum replicates them
    out = jnp.where(last, out, jnp.zeros_like(out))
    return jax.lax.psum(out, axis)


# ---------------------------------------------------------------------------
# composition with the SASG exchange (models.model.PipelineDef consumers)
# ---------------------------------------------------------------------------

def tree_get(tree, path: tuple):
    """Fetch a subtree by a (dict-key / sequence-index) path."""
    for k in path:
        tree = tree[k]
    return tree


def resolve_microbatches(batch_size: int, requested: int) -> int:
    """Largest microbatch count <= ``requested`` that divides the batch
    (the LASG probe sub-batch may not divide the configured count; 1 always
    works). Static ints only — runs at trace time.

    A ``requested`` the batch cannot honor degrades with a warning instead of
    silently: ``n_micro=1`` serializes the pipeline (every stage but one
    idles each tick), which is a real perf cliff the dryrun/metrics reader
    should see. ``requested <= 1`` is an explicit ask for no microbatching
    and stays silent.
    """
    req = min(max(requested, 1), batch_size)
    for nm in range(req, 1, -1):
        if batch_size % nm == 0:
            if nm != requested and requested > 1:
                import warnings

                warnings.warn(
                    f"resolve_microbatches: batch_size={batch_size} is not "
                    f"divisible by the requested {requested} microbatches; "
                    f"degrading to {nm}",
                    stacklevel=2,
                )
            return nm
    if requested > 1:
        import warnings

        warnings.warn(
            f"resolve_microbatches: batch_size={batch_size} has no divisor "
            f"<= requested {requested}; degrading to 1 microbatch (the "
            "pipeline serializes — only one stage is busy per tick)",
            stacklevel=2,
        )
    return 1


def build_pipelined_loss(
    pdef, axis: str = "stage", microbatches: int = 0,
    stage_local: bool = False,
) -> Callable:
    """Per-device loss for use inside a shard_map whose manual set contains
    ``axis``. ``params`` carries the LOCAL trunk slice (stage-sharded stacked
    layer dim); everything else is stage-replicated.

    With ``stage_local=False`` (the dense-combine fallback) the returned
    scalar is masked to stage 0. That mask makes the gradient stage-combine
    uniform (see ``build_pipelined_vag``): non-trunk params contribute to the
    device loss only on stage 0 (prepare feeds microbatches only through
    stage 0's ``first`` branch; finish is explicitly masked), so a plain psum
    over the stage axis reconstructs their true gradient — and the psum
    *transpose* inside ``pipeline_apply`` still broadcasts stage 0's output
    cotangent to the last stage, so the reverse ring delivers each stage its
    trunk slice's true gradient.

    With ``stage_local=True`` (the payload-gather hot path) the loss is the
    TRUE unmasked loss, replicated over the stage axis, and the gradients
    come out stage-LOCAL with no d-sized combine needed at all. The trick is
    a stop-gradient mask on the pipeline output::

        sg  = stop_gradient(out)
        out = sg + where(stage == 0, out - sg, 0)

    Values are untouched (``out`` is already stage-replicated by the ring's
    final psum), so every stage computes the true loss and — because
    ``finish`` reads no ``prepare_paths`` leaf — bit-identical, collective-
    free finish-side gradients. The cotangent flowing back into the ring's
    ``psum(out)``, however, is nonzero ONLY on stage 0, so the psum
    transpose all-reduces ``(ct, 0, ..., 0)``: an exact broadcast of the one
    true cotangent (adding zeros is fp-exact, no S-fold scaling for ANY
    stage count), and the backward ring then delivers each stage its true
    trunk-slice gradient, bitwise identical to the masked path. Prepare-side
    gradients are true on stage 0 and exactly zero elsewhere (microbatches
    enter only through stage 0's ``first`` branch); the tiny psum that
    finishes them lives in ``build_stage_local_grads``.
    """

    def loss_fn(params, batch):
        wseg = tree_get(params, pdef.trunk_path)
        h = pdef.prepare(params, batch)
        b = h.shape[0]
        n_micro = resolve_microbatches(
            b, microbatches or jax.lax.psum(1, axis)
        )
        micro = h.reshape((n_micro, b // n_micro) + h.shape[1:])
        layers_local = jax.tree.leaves(wseg)[0].shape[0]
        stage_fn = build_pipelined_forward(pdef.layer_fn, layers_local, axis)
        out = pipeline_apply(stage_fn, wseg, micro, axis)
        if stage_local:
            sg = jax.lax.stop_gradient(out)
            out = sg + jnp.where(
                jax.lax.axis_index(axis) == 0, out - sg, jnp.zeros_like(out)
            )
        h = out.reshape((b,) + out.shape[2:])
        loss = pdef.finish(params, h, batch)
        if stage_local:
            return loss
        return jnp.where(jax.lax.axis_index(axis) == 0, loss, 0.0)

    return loss_fn


def build_stage_local_grads(pdef, axis: str = "stage") -> Callable:
    """Finalize the stage-local gradient tree of the ``stage_local`` loss.

    Only the ``pdef.prepare_paths`` leaves need a collective: their grads
    are true on stage 0 and exactly zero elsewhere, so a psum (through the
    ``repro.comm`` seam) restores them everywhere by adding exact zeros —
    for the paper nets this is a few KB (stem + first norm), not the d-sized
    trunk. Finish-side grads are already bit-identical across stages (they
    are computed from the stage-replicated activations and cotangents), and
    trunk grads deliberately STAY stage-local: the transport compresses the
    local slice and gathers only the k-sized payload.
    """
    from repro.dist.sharding import _path_keys

    assert pdef.prepare_paths is not None, (
        "stage-local gradients need PipelineDef.prepare_paths (a model whose "
        "prepare/finish param reads are disjoint)"
    )
    prefixes = tuple(tuple(str(k) for k in p) for p in pdef.prepare_paths)

    def fix(path, g):
        keys = _path_keys(path)
        if any(keys[: len(p)] == list(p) for p in prefixes):
            return comm_collectives.psum_tree(g, (axis,))
        return g

    def gather(grads):
        return jax.tree_util.tree_map_with_path(fix, grads)

    return gather


def build_stage_combine(pdef, axis: str = "stage") -> Callable:
    """Per-stage gradient combine: trunk slices all-gather back to the full
    stacked form (replicated over the stage axis); everything else is a
    stage-0-masked partial gradient and psums to its true value.

    This is the stage composition the ``repro.comm`` Transport applies
    (``Transport.gather``) so the exchange — selection rule, error feedback,
    compression, worker all-gather, densify — always operates on the FULL
    gradient tree, identical to the non-pipelined step."""
    from repro.dist.sharding import _path_keys

    prefix = tuple(str(k) for k in pdef.trunk_path)

    def combine(path, x):
        keys = _path_keys(path)
        # trunk slice -> tiled all-gather (full stacked trunk, replicated);
        # stage-0-masked partial grad -> psum to its true value. Both are
        # d-sized over stages and owned by the repro.comm seam (audited).
        is_trunk = keys[: len(prefix)] == list(prefix)
        return comm_collectives.stage_combine_leaf(x, axis, is_trunk)

    def gather(grads):
        return jax.tree_util.tree_map_with_path(combine, grads)

    return gather


# ---------------------------------------------------------------------------
# 1F1B schedule (the default engine since the compressed-activation-ring PR)
# ---------------------------------------------------------------------------

def _tree_set(tree, path: tuple, value):
    """Return ``tree`` with the subtree at ``path`` replaced (dict trees)."""
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = _tree_set(tree[path[0]], path[1:], value)
    return out


def _batch_rows(batch, lo: int, hi: int, b: int):
    """Static row slice of every batch leaf with a leading batch dim."""
    return jax.tree.map(
        lambda v: v[lo:hi]
        if (hasattr(v, "ndim") and v.ndim >= 1 and v.shape[0] == b) else v,
        batch,
    )


def pipeline_vag_1f1b(pdef, params, batch, axis: str = "stage",
                      microbatches: int = 0, act_layout=None,
                      stage_local: bool = False):
    """One-forward-one-backward pipelined value-and-grad. Call inside a
    shard_map whose manual set contains ``axis``.

    Schedule: microbatch ``i`` runs forward on stage ``s`` at tick
    ``t = i + s`` and backward at ``t = i + 2(S-1) - s`` — the last stage
    turns each microbatch around in the same tick, so from tick ``S-1`` on
    every stage alternates one forward with one backward. Total
    ``n + 2(S-1)`` ticks (statically unrolled; forward runs only in ticks
    ``[0, n+S-2]``, backward only in ``[S-1, n+2S-3]``, so per-stage work is
    GPipe's tick count in each direction). Unlike GPipe-under-autodiff,
    which keeps every microbatch's autodiff residuals live until the loop
    ends, in-flight state here is a ``2S-1``-slot stash of the
    stage-forward VJP RESIDUALS (``jax.vjp`` closures are pytrees with one
    treedef for every tick). The loop is statically unrolled, so each slot
    is plain per-tick values in a Python list; the backward picks its
    stage's slot with an ``S-1``-deep stage-index select — no ring-buffer
    stacking or copy traffic — and rebuilds the cotangent function from the
    stashed leaves: no forward recompute, and live residuals stay O(S)
    microbatches per stage for any ``n``.

    The wire is owned by ``comm.transport.ActivationLayout``: every forward
    carry, backward cotangent carry, and the finished-output broadcast is
    ``encode``d to its wire parts and moved by the ``repro.comm`` ring
    collectives. The default identity layout is bit-exact (and the broadcast
    degenerates to GPipe's ``psum(where(last, out, 0))``); compressed
    layouts decode to the SAME values on every stage, so losses/gradients
    stay stage-consistent (the gradient is exact for the compressed-forward
    computation).

    Numerics contract: ``pdef.finish`` must be a mean over leading-dim
    examples (true for every model here — CE/MSE means), so seeding each
    microbatch's loss-vjp with ``1/n_micro`` reproduces the full-batch
    cotangent; for power-of-two microbatch splits this is bit-exact, else
    fp-reassociation-level (same tier as GPipe's microbatch accumulation).

    Returns ``(loss, grads)`` with the true loss replicated over the stage
    axis. ``stage_local=False``: non-trunk grads are stage-0-masked partials
    (the dense ``build_stage_combine`` psum/gather semantics); ``True``:
    finish-side grads replicated, prepare-side grads true on stage 0 and
    zero elsewhere, trunk grads stage-local — the payload-gather contract of
    ``build_stage_local_grads``.
    """
    from repro.comm.transport import ActivationLayout

    layout = act_layout or ActivationLayout()

    wseg = tree_get(params, pdef.trunk_path)
    # prepare's vjp is taken NOW so its forward runs once (the residuals
    # ride through the loop; the cotangent seed arrives after the drain)
    h, prep_vjp = jax.vjp(lambda p: pdef.prepare(p, batch), params)
    b = h.shape[0]
    S = jax.lax.psum(1, axis)        # static axis size (concrete-operand psum)
    n = resolve_microbatches(b, microbatches or S)
    mb = b // n
    micro_x = h.reshape((n, mb) + h.shape[1:])
    layers_local = jax.tree.leaves(wseg)[0].shape[0]
    stage_fn = build_pipelined_forward(pdef.layer_fn, layers_local, axis)

    s_idx = jax.lax.axis_index(axis)
    first = s_idx == 0
    last = s_idx == S - 1
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]
    bwd_perm = [(i, (i - 1) % S) for i in range(S)]

    T = n + 2 * (S - 1)              # total ticks
    W = 2 * S - 1                    # stash depth: max fwd->bwd lag + 1
    inv_n = 1.0 / n

    act_shape = micro_x.shape[1:]
    act_dtype = micro_x.dtype
    zero_act = jnp.zeros(act_shape, act_dtype)
    fwd_parts = layout.encode(zero_act)
    bwd_parts = layout.encode(zero_act)
    out = jnp.zeros_like(micro_x)
    # residual stash: W static slots of the stage-forward vjp closure's
    # leaves (one treedef for every tick — same function, same shapes)
    _, _vjp0 = jax.vjp(stage_fn, wseg, zero_act)
    _res0, res_tree = jax.tree.flatten(_vjp0)
    stash = [list(_res0) for _ in range(W)]
    dwseg = jax.tree.map(jnp.zeros_like, wseg)
    dmicro = jnp.zeros_like(micro_x)
    y = zero_act
    dx = zero_act
    dy = zero_act

    def mb_loss(yy, i):
        # per-microbatch finish loss on the matching static batch rows
        return pdef.finish(params, yy, _batch_rows(batch, i * mb, (i + 1) * mb, b))

    for t in range(T):
        do_fwd = t <= n + S - 2
        do_bwd = S - 1 <= t <= T - 1
        if do_fwd:
            # stage 0 feeds fresh microbatches (re-feeding the last one on
            # drain ticks — never lands in ``out``); later stages decode what
            # the ring delivered last tick.
            x_in = jnp.where(
                first, micro_x[min(t, n - 1)],
                layout.decode(fwd_parts, act_shape, act_dtype),
            )
            y, fvjp_t = jax.vjp(stage_fn, wseg, x_in)
            stash[t % W] = jax.tree.leaves(fvjp_t)
            done = t - (S - 1)       # microbatch finishing at this tick
            if 0 <= done < n:
                out = out.at[done].set(y)
                # last stage turns the microbatch around NOW (the 1F1B in
                # 1F1B): its loss cotangent seeds this same tick's backward
                _ly, fvjp = jax.vjp(lambda yy: mb_loss(yy, done), y)
                (dy,) = fvjp(jnp.full((), inv_n, _ly.dtype))
        if do_bwd:
            ct = jnp.where(
                last, dy, layout.decode(bwd_parts, act_shape, act_dtype)
            )
            # stage s backs up microbatch i_b = t - 2(S-1) + s this tick;
            # out-of-range ticks compute on garbage carries and are masked
            i_b = t - 2 * (S - 1) + s_idx
            valid = (i_b >= 0) & (i_b < n)
            # stage s reads the slot its forward wrote at tick t - 2(S-1-s);
            # t is static, so the choice is an (S-1)-deep select on s_idx
            # over plain slot values (out-of-range ticks read stale/zero
            # slots and are masked by ``valid`` below)
            leaves = stash[(t - 2 * (S - 1)) % W]
            for sj in range(1, S):
                cand = stash[(t - 2 * (S - 1 - sj)) % W]
                leaves = [
                    jnp.where(s_idx == sj, c, l)
                    for l, c in zip(leaves, cand)
                ]
            svjp = jax.tree.unflatten(res_tree, leaves)
            dw, dx = svjp(ct)
            dwseg = jax.tree.map(
                lambda acc, d: acc + jnp.where(valid, d, jnp.zeros_like(d)),
                dwseg, dw,
            )
            i0 = t - 2 * (S - 1)     # static: stage 0's microbatch this tick
            if 0 <= i0 < n:
                # only stage 0's dx is d(loss)/d(micro_x[i0])
                dmicro = dmicro.at[i0].set(
                    jnp.where(first, dx, jnp.zeros_like(dx))
                )
        # ring hops for next tick, in wire layout
        if do_fwd and t < n + S - 2:
            fwd_parts = comm_collectives.ring_shift_parts(
                layout.encode(y), axis, fwd_perm
            )
        if do_bwd and t < T - 1:
            bwd_parts = comm_collectives.ring_shift_parts(
                layout.encode(dx), axis, bwd_perm
            )

    # replicate the finished outputs: encode once, mask to the last stage,
    # psum the parts, decode — every stage decodes the SAME values (identity
    # layout == GPipe's psum(where(last, out, 0)) bitwise)
    out_parts = comm_collectives.ring_broadcast_parts(
        layout.encode(out), axis, last
    )
    out = layout.decode(out_parts, out.shape, out.dtype)
    h_all = out.reshape((b,) + out.shape[2:])

    # loss + finish-side param grads, once, from the replicated outputs
    loss, fvjp = jax.vjp(lambda p: pdef.finish(p, h_all, batch), params)
    (g_fin,) = fvjp(jnp.ones((), loss.dtype))
    # prepare-side param grads, seeded by stage 0's input cotangents (zero
    # elsewhere — microbatches enter the pipe only through stage 0)
    (g_prep,) = prep_vjp(dmicro.reshape((b,) + dmicro.shape[2:]))
    g = jax.tree.map(jnp.add, g_fin, g_prep)
    if not stage_local:
        # dense-combine contract: non-trunk grads are stage-0-masked
        # partials, so the downstream stage psum reconstructs them exactly
        # (handles tied prepare/finish reads: masked sum psums to fin+prep)
        g = jax.tree.map(
            lambda x: jnp.where(first, x, jnp.zeros_like(x)), g
        )
    g = _tree_set(g, tuple(pdef.trunk_path), dwseg)
    return loss, g


def build_pipelined_vag(
    pdef, axis: str = "stage", microbatches: int = 0, combine: bool = True,
    stage_local: bool = False, act_layout=None, engine: str = "1f1b",
) -> Callable:
    """Pipelined drop-in for ``jax.value_and_grad(model.loss_fn)`` inside the
    worker shard_map region. With ``combine=True`` (the standalone default)
    the returned grads are the FULL tree replicated over the stage axis
    (trunk all-gathered via ``build_stage_combine``). The train step passes
    ``combine=False`` and threads ``build_stage_combine`` into the exchange
    instead: the ``repro.comm`` Transport owns the stage gather, so both the
    fresh and the stale-params auxiliary gradient (paper eq. 6/7 pairing)
    are combined at the transport seam.

    ``stage_local=True`` selects the payload-gather hot path: the loss is
    the true replicated loss (no psum needed), trunk grads stay stage-local
    for the transport's k-sized payload gather, and only the tiny
    ``prepare_paths`` grads cross the stage axis
    (``build_stage_local_grads``). Mutually exclusive with ``combine``.

    ``engine`` selects the schedule: ``"1f1b"`` (default — interleaved
    forward/backward with rematerialization and the ``act_layout``-owned
    compressed ring, ``pipeline_vag_1f1b``) or ``"gpipe"`` (the synchronous
    autodiff-through-``pipeline_apply`` loop, kept as the reference engine
    for the benchmark comparison and the bitwise lint-baselined ring sites).
    ``act_layout`` (a ``comm.transport.ActivationLayout``) only affects the
    1F1B engine; GPipe always moves dense fp32 activations.
    """
    if engine not in ("1f1b", "gpipe"):
        raise ValueError(f"unknown pipeline engine {engine!r}")

    if engine == "1f1b":
        finalize = build_stage_local_grads(pdef, axis) if stage_local else None
        gather = (
            build_stage_combine(pdef, axis)
            if combine and not stage_local else None
        )
        if stage_local:
            assert not combine, "stage_local grads replace the dense combine"

        def vag_1f1b(params, batch):
            loss, g = pipeline_vag_1f1b(
                pdef, params, batch, axis, microbatches,
                act_layout=act_layout, stage_local=stage_local,
            )
            if finalize is not None:
                g = finalize(g)
            elif gather is not None:
                g = gather(g)
            return loss, g

        return vag_1f1b

    if stage_local:
        assert not combine, "stage_local grads replace the dense combine"
        loss_fn = build_pipelined_loss(pdef, axis, microbatches, stage_local=True)
        vag = jax.value_and_grad(loss_fn)
        finalize = build_stage_local_grads(pdef, axis)

        def stage_local_vag(params, batch):
            loss, g = vag(params, batch)
            return loss, finalize(g)

        return stage_local_vag

    loss_fn = build_pipelined_loss(pdef, axis, microbatches)
    vag = jax.value_and_grad(loss_fn)
    gather = build_stage_combine(pdef, axis) if combine else None

    def pipelined_vag(params, batch):
        loss, g = vag(params, batch)
        # scalar: the stage-0-masked loss psums to the true loss
        loss = comm_collectives.psum_scalar(loss, (axis,))
        return loss, (gather(g) if gather is not None else g)

    return pipelined_vag

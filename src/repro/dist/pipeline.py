"""GPipe-style microbatch pipeline parallelism over a mesh stage axis.

The layer stack is split into S contiguous stages along a (manual) mesh
axis; microbatches stream through the stages with activations handed to the
next stage by a ring ``ppermute`` each tick. After ``n_micro + S - 1`` ticks
every microbatch has traversed every stage; the last stage's outputs are
psum-broadcast so the result is replicated over the stage axis (out_specs
``P()``), numerically identical to applying all ``S * layers_per_stage``
layers sequentially (tests/test_pipeline.py).

This is orthogonal to the SASG exchange: pipeline_apply runs inside a
shard_map whose manual set contains the stage axis, and composes with auto
TP axes the same way the worker exchange does.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def build_pipelined_forward(layer_fn: Callable, layers_per_stage: int,
                            axis: str = "stage") -> Callable:
    """Fold ``layers_per_stage`` applications of ``layer_fn`` into one stage.

    ``layer_fn(w, h) -> h`` consumes one layer's params; the returned
    ``stage_fn(wseg, h)`` consumes the stage's params stacked on a leading
    ``layers_per_stage`` dim (array or pytree of arrays). ``axis`` names the
    stage axis for documentation/symmetry with ``pipeline_apply``.
    """

    def stage_fn(wseg, h):
        for l in range(layers_per_stage):
            h = layer_fn(jax.tree.map(lambda w: w[l], wseg), h)
        return h

    return stage_fn


def pipeline_apply(stage_fn: Callable, wseg, micro_x: jax.Array,
                   axis: str = "stage") -> jax.Array:
    """Run microbatches through the stage pipeline. Call inside shard_map.

    ``wseg`` is this stage's params (stage-stacked dim already stripped);
    ``micro_x`` is the full (n_micro, mb, ...) microbatch array, replicated
    over the stage axis. Returns the fully-processed (n_micro, mb, ...)
    outputs, replicated over the stage axis.
    """
    n_micro = micro_x.shape[0]
    S = jax.lax.psum(1, axis)        # static axis size (concrete-operand psum)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % S) for i in range(S)]
    first = idx == 0
    last = idx == S - 1

    carry = jnp.zeros_like(micro_x[0])
    out = jnp.zeros_like(micro_x)
    for t in range(n_micro + S - 1):
        # stage 0 feeds fresh microbatches (re-feeding the final one during
        # drain ticks — those results never land in ``out``); later stages
        # consume what the ring delivered last tick.
        x_in = jnp.where(first, micro_x[min(t, n_micro - 1)], carry)
        y = stage_fn(wseg, x_in)
        done = t - (S - 1)           # microbatch completing at this tick
        if 0 <= done < n_micro:
            out = out.at[done].set(y)
        carry = jax.lax.ppermute(y, axis, perm)

    # only the last stage holds finished microbatches; psum replicates them
    out = jnp.where(last, out, jnp.zeros_like(out))
    return jax.lax.psum(out, axis)

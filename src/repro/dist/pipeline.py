"""GPipe-style microbatch pipeline parallelism over a mesh stage axis.

The layer stack is split into S contiguous stages along a (manual) mesh
axis; microbatches stream through the stages with activations handed to the
next stage by a ring ``ppermute`` each tick. After ``n_micro + S - 1`` ticks
every microbatch has traversed every stage; the last stage's outputs are
psum-broadcast so the result is replicated over the stage axis, numerically
identical to applying all ``S * layers_per_stage`` layers sequentially —
forward and backward both, covered by ``tests/test_pipeline.py`` (2- and
4-stage, values and grads).

Composition with the SASG exchange (strategy -> sharding -> pipeline ->
step): ``train/step.py`` places the stage axis in the shard_map manual set
next to the worker axes, hands each stage its slice of the model's
stage-stacked trunk params (``dist.sharding.param_specs`` with
``stage_axis``/``trunk_paths``), and swaps the exchange's ``grad_fn`` for
``build_pipelined_vag`` — so the fresh gradient AND the stale-params
auxiliary gradient of the LASG rule (paper eq. 6/7) run through the same
pipelined forward/backward, preserving the same-minibatch variance
cancellation. The returned gradient is the FULL tree replicated over the
stage axis (trunk all-gathered, the rest psum-combined via the stage-0 loss
mask), so the selection rule, error feedback, top-k compression, and the
worker-axis exchange are bit-identical to the non-pipelined step
(``tests/test_pipeline_sasg.py``). Auto TP axes compose transparently, as
in the worker exchange.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def build_pipelined_forward(layer_fn: Callable, layers_per_stage: int,
                            axis: str = "stage") -> Callable:
    """Fold ``layers_per_stage`` applications of ``layer_fn`` into one stage.

    ``layer_fn(w, h) -> h`` consumes one layer's params; the returned
    ``stage_fn(wseg, h)`` consumes the stage's params stacked on a leading
    ``layers_per_stage`` dim (array or pytree of arrays). ``axis`` names the
    stage axis for documentation/symmetry with ``pipeline_apply``.
    """

    def stage_fn(wseg, h):
        for l in range(layers_per_stage):
            h = layer_fn(jax.tree.map(lambda w: w[l], wseg), h)
        return h

    return stage_fn


def pipeline_apply(stage_fn: Callable, wseg, micro_x: jax.Array,
                   axis: str = "stage") -> jax.Array:
    """Run microbatches through the stage pipeline. Call inside shard_map.

    ``wseg`` is this stage's params (stage-stacked dim already stripped);
    ``micro_x`` is the full (n_micro, mb, ...) microbatch array, replicated
    over the stage axis. Returns the fully-processed (n_micro, mb, ...)
    outputs, replicated over the stage axis.
    """
    n_micro = micro_x.shape[0]
    S = jax.lax.psum(1, axis)        # static axis size (concrete-operand psum)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % S) for i in range(S)]
    first = idx == 0
    last = idx == S - 1

    carry = jnp.zeros_like(micro_x[0])
    out = jnp.zeros_like(micro_x)
    for t in range(n_micro + S - 1):
        # stage 0 feeds fresh microbatches (re-feeding the final one during
        # drain ticks — those results never land in ``out``); later stages
        # consume what the ring delivered last tick.
        x_in = jnp.where(first, micro_x[min(t, n_micro - 1)], carry)
        y = stage_fn(wseg, x_in)
        done = t - (S - 1)           # microbatch completing at this tick
        if 0 <= done < n_micro:
            out = out.at[done].set(y)
        carry = jax.lax.ppermute(y, axis, perm)

    # only the last stage holds finished microbatches; psum replicates them
    out = jnp.where(last, out, jnp.zeros_like(out))
    return jax.lax.psum(out, axis)


# ---------------------------------------------------------------------------
# composition with the SASG exchange (models.model.PipelineDef consumers)
# ---------------------------------------------------------------------------

def tree_get(tree, path: tuple):
    """Fetch a subtree by a (dict-key / sequence-index) path."""
    for k in path:
        tree = tree[k]
    return tree


def resolve_microbatches(batch_size: int, requested: int) -> int:
    """Largest microbatch count <= ``requested`` that divides the batch
    (the LASG probe sub-batch may not divide the configured count; 1 always
    works). Static ints only — runs at trace time."""
    for nm in range(min(max(requested, 1), batch_size), 1, -1):
        if batch_size % nm == 0:
            return nm
    return 1


def build_pipelined_loss(
    pdef, axis: str = "stage", microbatches: int = 0
) -> Callable:
    """Per-device loss for use inside a shard_map whose manual set contains
    ``axis``. ``params`` carries the LOCAL trunk slice (stage-sharded stacked
    layer dim); everything else is stage-replicated.

    The returned scalar is masked to stage 0. That mask makes the gradient
    stage-combine uniform (see ``build_pipelined_vag``): non-trunk params
    contribute to the device loss only on stage 0 (prepare feeds microbatches
    only through stage 0's ``first`` branch; finish is explicitly masked), so
    a plain psum over the stage axis reconstructs their true gradient — and
    the psum *transpose* inside ``pipeline_apply`` still broadcasts stage 0's
    output cotangent to the last stage, so the reverse ring delivers each
    stage its trunk slice's true gradient.
    """

    def loss_fn(params, batch):
        wseg = tree_get(params, pdef.trunk_path)
        h = pdef.prepare(params, batch)
        b = h.shape[0]
        n_micro = resolve_microbatches(
            b, microbatches or jax.lax.psum(1, axis)
        )
        micro = h.reshape((n_micro, b // n_micro) + h.shape[1:])
        layers_local = jax.tree.leaves(wseg)[0].shape[0]
        stage_fn = build_pipelined_forward(pdef.layer_fn, layers_local, axis)
        out = pipeline_apply(stage_fn, wseg, micro, axis)
        h = out.reshape((b,) + out.shape[2:])
        loss = pdef.finish(params, h, batch)
        return jnp.where(jax.lax.axis_index(axis) == 0, loss, 0.0)

    return loss_fn


def build_stage_combine(pdef, axis: str = "stage") -> Callable:
    """Per-stage gradient combine: trunk slices all-gather back to the full
    stacked form (replicated over the stage axis); everything else is a
    stage-0-masked partial gradient and psums to its true value.

    This is the stage composition the ``repro.comm`` Transport applies
    (``Transport.gather``) so the exchange — selection rule, error feedback,
    compression, worker all-gather, densify — always operates on the FULL
    gradient tree, identical to the non-pipelined step."""
    from repro.dist.sharding import _path_keys

    prefix = tuple(str(k) for k in pdef.trunk_path)

    def combine(path, x):
        keys = _path_keys(path)
        if keys[: len(prefix)] == list(prefix):
            # per-stage trunk slice -> full stacked trunk, replicated
            return jax.lax.all_gather(x, axis, axis=0, tiled=True)
        # stage-0-masked partial grad -> true grad (zero on stages != 0)
        return jax.lax.psum(x, axis)

    def gather(grads):
        return jax.tree_util.tree_map_with_path(combine, grads)

    return gather


def build_pipelined_vag(
    pdef, axis: str = "stage", microbatches: int = 0, combine: bool = True
) -> Callable:
    """Pipelined drop-in for ``jax.value_and_grad(model.loss_fn)`` inside the
    worker shard_map region. With ``combine=True`` (the standalone default)
    the returned grads are the FULL tree replicated over the stage axis
    (trunk all-gathered via ``build_stage_combine``). The train step passes
    ``combine=False`` and threads ``build_stage_combine`` into the exchange
    instead: the ``repro.comm`` Transport owns the stage gather, so both the
    fresh and the stale-params auxiliary gradient (paper eq. 6/7 pairing)
    are combined at the transport seam."""
    loss_fn = build_pipelined_loss(pdef, axis, microbatches)
    vag = jax.value_and_grad(loss_fn)
    gather = build_stage_combine(pdef, axis) if combine else None

    def pipelined_vag(params, batch):
        loss, g = vag(params, batch)
        loss = jax.lax.psum(loss, axis)
        return loss, (gather(g) if gather is not None else g)

    return pipelined_vag

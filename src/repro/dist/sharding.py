"""Role-aware PartitionSpec assignment for FSDP x TP layouts.

The model zoo stores weights as nested dicts with conventional leaf names
(layers.py "Conventions"), so specs are assigned from the leaf's *path*:

- column-parallel (input dim -> fsdp, output dim -> tp): wq/wk/wv, w_gate/
  w_up, w_in, lm_head, and any unrecognized >=2-D leaf (the safe default);
- row-parallel (input dim -> tp, output dim -> fsdp): wo, w_down, w_out;
- vocab-parallel embedding: embed -> (tp, fsdp);
- expert-parallel MoE: experts_* shard the expert dim over tp when
  divisible, otherwise fall back to TP over d_expert;
- 1-D leaves (norm scales, biases, gates) are replicated.

A dim is only sharded when its size divides the mesh axis size; stacked
leading layer axes (the scan-over-units layout) are padded with None. All
three entry points accept either concrete arrays or ShapeDtypeStructs.

Pipeline composition: leaves under a *trunk path* (``Model.pipeline``'s
homogeneous stage-stacked layer stack, leading dim = trunk depth) take the
``stage_axis`` on that stacked dim — each pipeline stage owns a contiguous
block of layers — while their trailing dims keep the normal role-aware
FSDP x TP assignment. Everything outside the trunk ignores ``stage_axis``
(replicated over stages), matching the stage-masked gradient combine in
``dist.pipeline``.
"""
from __future__ import annotations

import math
from typing import Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_ROW_PARALLEL = {"wo", "w_down", "w_out"}

# Leaves whose natural (unstacked) form is a vector — norm scales, biases,
# per-head gates. They pick up leading layer dims under the scan-over-units
# layout, so rank alone cannot identify them; replicate by name.
_VECTOR = {"scale", "bias", "b", "lam", "a_log", "dt_bias", "d_skip", "norm_scale"}


def _axis_size(mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(_axis_size(mesh, a) for a in axis)
    return mesh.shape[axis]


def _fit(mesh, dim: int, axis: Axis):
    """``axis`` if ``dim`` divides evenly over it, else None (no sharding)."""
    if axis is None:
        return None
    size = _axis_size(mesh, axis)
    if size <= 1 or dim % size != 0:
        return None
    return tuple(axis) if isinstance(axis, list) else axis


def _path_keys(path) -> list:
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]


def param_specs(params, mesh, fsdp_axis: Axis, tp_axis: Axis,
                stage_axis: Axis = None, trunk_paths: Tuple = ()):
    """PartitionSpec tree for a parameter pytree (same structure).

    ``trunk_paths`` is a tuple of leaf-path prefixes (tuples of path keys as
    strings) naming stage-stacked trunk subtrees; when ``stage_axis`` is set,
    their leaves shard the stacked leading layer dim over it (module
    docstring "Pipeline composition").
    """
    prefixes = tuple(tuple(str(k) for k in p) for p in trunk_paths)

    def role_entries(key, shape) -> tuple:
        """Role-aware entries for one (possibly trunk-stripped) leaf shape."""
        ndim = len(shape)
        if ndim <= 1 or key in _VECTOR:
            return (None,) * ndim  # norm scales / biases / gates: replicated

        if key.startswith("experts_") and ndim >= 3:
            e, a, b = shape[-3:]
            if _fit(mesh, e, tp_axis) is not None:
                # expert-parallel: expert dim over tp, d_model dim over fsdp
                if key == "experts_down":
                    spec3 = (tp_axis, None, _fit(mesh, b, fsdp_axis))
                else:
                    spec3 = (tp_axis, _fit(mesh, a, fsdp_axis), None)
            elif key == "experts_down":
                # fallback: TP over d_expert (row-parallel within the expert)
                spec3 = (None, _fit(mesh, a, tp_axis), _fit(mesh, b, fsdp_axis))
            else:
                spec3 = (None, _fit(mesh, a, fsdp_axis), _fit(mesh, b, tp_axis))
            return (None,) * (ndim - 3) + spec3

        if key == "embed":
            # vocab-parallel embedding (logits reduce over tp at the head)
            return (_fit(mesh, shape[0], tp_axis), _fit(mesh, shape[1], fsdp_axis))

        if key in _ROW_PARALLEL:
            d2 = (_fit(mesh, shape[-2], tp_axis), _fit(mesh, shape[-1], fsdp_axis))
        else:
            d2 = (_fit(mesh, shape[-2], fsdp_axis), _fit(mesh, shape[-1], tp_axis))
        return (None,) * (ndim - 2) + d2

    def leaf(path, x):
        keys = _path_keys(path)
        shape = tuple(x.shape)
        if (
            stage_axis is not None
            and shape
            and any(keys[: len(p)] == list(p) for p in prefixes)
        ):
            # stage-stacked trunk leaf: stage over the stacked layer dim,
            # role-aware assignment for the per-layer trailing dims
            return P(_fit(mesh, shape[0], stage_axis),
                     *role_entries(keys[-1], shape[1:]))
        if len(shape) <= 1 or keys[-1] in _VECTOR:
            return P()  # norm scales / biases / per-head gates: replicated
        return P(*role_entries(keys[-1], shape))

    return jax.tree_util.tree_map_with_path(leaf, params)


def stage_only_spec(spec, stage_axis: Axis):
    """Keep ONLY the manual stage axis of a param spec: the shard_map region
    spec that hands each stage its contiguous trunk slice (all auto axes
    dropped — they partition inside the region automatically)."""
    from jax.sharding import PartitionSpec as P

    return P(*[e if (stage_axis is not None and e == stage_axis) else None
               for e in tuple(spec)])


def strip_stage_spec(spec, stage_axis: Axis):
    """A param spec with the manual stage axis stripped (auto axes only):
    the layout of quantities that live in the FULL-gradient exchange domain
    (stage-replicated EF buffers on the dense-combine fallback, exchange
    leaf specs, the densified update)."""
    from jax.sharding import PartitionSpec as P

    return P(*[None if (stage_axis is not None and e == stage_axis) else e
               for e in tuple(spec)])


def ef_specs(pspecs, stage_axis: Axis, stage_sharded: bool):
    """Sharding specs for the error-feedback (compressor-state) buffers.

    The EF tree mirrors the params tree. On the payload-gather hot path
    (``stage_sharded=True``) the trunk EF buffers are stage-SHARDED exactly
    like the params — each stage owns the residuals of its own trunk slice,
    d/S memory per device, and the checkpointed logical array keeps the
    FULL shape so restore onto a different stage count is pure resharding
    (core.error_feedback.remap_error_state). On the dense-combine fallback
    the EF buffers live in the full-gradient domain and stay
    stage-replicated (stage axis stripped)."""
    from jax.sharding import PartitionSpec as P

    if stage_sharded:
        return pspecs
    return jax.tree.map(
        lambda s: strip_stage_spec(s, stage_axis), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_specs(batch, mesh, data_axis: Axis):
    """Leading (batch) dim over the data axes; everything else replicated."""

    def leaf(x):
        shape = tuple(x.shape)
        if not shape:
            return P()
        return P(_fit(mesh, shape[0], data_axis), *([None] * (len(shape) - 1)))

    return jax.tree.map(leaf, batch)


def cache_specs(cache, mesh, data_axis: Axis, tp_axis: Axis):
    """Decode-cache specs: batch dim over data, KV head dim over tp.

    Handles both the scan-over-units stacked layout (leading n_units dim
    under the "unit" subtree) and flat per-layer ("rem") states. Ring-buffer
    position tables ("pos"/"ppos") and block tables ("bt") are tiny and stay
    replicated. Paged block pools ("pk"/"pv", shape (num_blocks, block,
    Hkv, Dh)) shard the pool dim over data — capacity scales with devices;
    block gathers cross shards, which XLA lowers to collectives — and keep
    the head dim over tp like dense k/v.
    """

    def leaf(path, x):
        keys = _path_keys(path)
        key = keys[-1]
        shape = tuple(x.shape)
        ndim = len(shape)
        b = 1 if "unit" in keys else 0  # stacked leading layer axis
        if key in ("pos", "ppos", "bt") or ndim <= b + 1:
            return P()
        entries = [None] * ndim
        entries[b] = _fit(mesh, shape[b], data_axis)
        if key in ("k", "v", "pk", "pv") and ndim - b >= 3:
            entries[-2] = _fit(mesh, shape[-2], tp_axis)  # (.., H, Dh) heads
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf, cache)

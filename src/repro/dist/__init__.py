"""Distribution layer: mesh strategies, sharding rules, pipeline parallelism.

Layering (bottom-up; see README "repro.dist layering"):

- ``strategy``: which mesh axes are SASG workers vs auto FSDP/TP axes, and
  the flat / hierarchical / plain selection policy (``choose_strategy``).
- ``sharding``: role-aware PartitionSpec trees for params / batches / KV
  caches, consumed by the train step, the serve engine, and the dry-runs.
- ``pipeline``: GPipe-style microbatch pipeline parallelism over a manual
  stage axis, composed with the SASG exchange by ``train/step.py`` through
  ``build_pipelined_vag`` (strategy -> sharding -> pipeline -> step).
"""
from .strategy import Strategy, choose_strategy
from .sharding import batch_specs, cache_specs, param_specs
from .pipeline import (
    build_pipelined_forward,
    build_pipelined_loss,
    build_pipelined_vag,
    pipeline_apply,
    resolve_microbatches,
)

__all__ = [
    "Strategy",
    "choose_strategy",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "build_pipelined_forward",
    "build_pipelined_loss",
    "build_pipelined_vag",
    "pipeline_apply",
    "resolve_microbatches",
]

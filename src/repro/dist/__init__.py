"""Distribution layer: mesh strategies, sharding rules, pipeline parallelism.

Layering (bottom-up; see README "Layering: repro.dist x the repro.comm
transport seam"):

- ``strategy``: which mesh axes are SASG workers vs auto FSDP/TP axes, and
  the flat / hierarchical / plain selection policy (``choose_strategy``).
- ``sharding``: role-aware PartitionSpec trees for params / batches / KV
  caches, consumed by the train step, the serve engine, and the dry-runs.
- ``pipeline``: GPipe-style microbatch pipeline parallelism over a manual
  stage axis. On the payload-gather hot path the train step runs
  ``build_pipelined_vag(stage_local=True)`` — gradients stay stage-local
  and the ``repro.comm`` Transport gathers only the k-sized compressed
  payload over the stage axis; compressors whose support depends on
  cross-slice state instead use ``build_pipelined_vag(combine=False)`` with
  the dense per-stage combine (``build_stage_combine``) threaded into the
  Transport (strategy -> sharding -> pipeline -> transport -> step).
"""
from .strategy import Strategy, choose_strategy
from .sharding import (
    batch_specs,
    cache_specs,
    ef_specs,
    param_specs,
    stage_only_spec,
    strip_stage_spec,
)
from .pipeline import (
    build_pipelined_forward,
    build_pipelined_loss,
    build_pipelined_vag,
    build_stage_combine,
    build_stage_local_grads,
    pipeline_apply,
    resolve_microbatches,
)

__all__ = [
    "Strategy",
    "choose_strategy",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "ef_specs",
    "stage_only_spec",
    "strip_stage_spec",
    "build_pipelined_forward",
    "build_pipelined_loss",
    "build_pipelined_vag",
    "build_stage_combine",
    "build_stage_local_grads",
    "pipeline_apply",
    "resolve_microbatches",
]

"""Distribution layer: mesh strategies, sharding rules, pipeline parallelism.

Layering (bottom-up; see README "Layering: repro.dist x the repro.comm
transport seam"):

- ``strategy``: which mesh axes are SASG workers vs auto FSDP/TP axes, and
  the flat / hierarchical / plain selection policy (``choose_strategy``).
- ``sharding``: role-aware PartitionSpec trees for params / batches / KV
  caches, consumed by the train step, the serve engine, and the dry-runs.
- ``pipeline``: GPipe-style microbatch pipeline parallelism over a manual
  stage axis. The train step runs the forward/backward through
  ``build_pipelined_vag(combine=False)`` and threads the per-stage gradient
  combine (``build_stage_combine``) into the ``repro.comm`` Transport, which
  applies it so the exchange always sees the full gradient tree
  (strategy -> sharding -> pipeline -> transport -> step).
"""
from .strategy import Strategy, choose_strategy
from .sharding import batch_specs, cache_specs, param_specs
from .pipeline import (
    build_pipelined_forward,
    build_pipelined_loss,
    build_pipelined_vag,
    build_stage_combine,
    pipeline_apply,
    resolve_microbatches,
)

__all__ = [
    "Strategy",
    "choose_strategy",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "build_pipelined_forward",
    "build_pipelined_loss",
    "build_pipelined_vag",
    "build_stage_combine",
    "pipeline_apply",
    "resolve_microbatches",
]

"""Mesh execution strategies for the SASG exchange (DESIGN.md §2/§6).

A ``Strategy`` names the role of every mesh axis for one training run:

- ``upload_axes``: manual shard_map axes whose slices are the SASG workers —
  each slice computes its own gradient, runs the LASG send/skip rule, and
  contributes one (possibly cached) compressed upload per step.
- ``grad_axes``: axes the *global batch* is split over. Superset of
  ``upload_axes``; the extra axes (in-pod data parallelism) stay auto, so
  the per-worker gradient mean over them is the automatic backward psum.
- ``fsdp_axis`` / ``tp_axis``: auto axes for parameter sharding
  (``dist.sharding.param_specs``).
- ``data_axis``: the auto data axis *inside* the worker region (None when
  workers are the finest data split).

Three strategies:

- ``"flat"``: every data-axis slice is a worker (the paper's M-worker
  setting). Params are worker-replicated, TP-sharded over ``tp_axis``.
- ``"hierarchical"``: on 3-D pod meshes each pod is one worker; the in-pod
  ``data`` axis stays auto. TP-only parameter sharding: FSDP over an auto
  axis *inside* the manual pod region trips an XLA SPMD partitioner CHECK
  (pinned in ``tests/test_known_limits.py``), so ``fsdp_axis`` is forced
  ``None`` until the partitioner is fixed.
- ``"plain"``: no shard_map — standard auto-SPMD data parallelism. Used as
  the non-SASG baseline and as the fallback whenever one worker replica of
  the parameters (plus SASG worker state) cannot fit beside the TP shards.

Pipeline parallelism composes with flat and hierarchical strategies: when
the mesh carries a ``stage`` axis and ``pipeline_stages >= 2`` is requested,
``stage_axis`` joins the manual shard_map set and the train step runs the
forward/backward through ``dist.pipeline.pipeline_apply`` (GPipe
microbatching), with the model's homogeneous trunk params stage-sharded on
their stacked layer dim. Fallbacks mirror the flat/hierarchical logic:

- no ``stage`` axis in the mesh -> no pipelining (knob silently ignored);
- trunk depth not divisible by the stage-axis size -> no pipelining (the
  stage axis stays in the mesh but everything is replicated over it);
- "plain" never pipelines (pipelining requires the shard_map region).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple, Union

Axis = Union[str, Tuple[str, ...], None]

# Per-worker replica cost model for the fit check: each SASG worker holds
# the fp32 parameters plus error-feedback and stale-parameter buffers of the
# same footprint — ~3x params_bytes, sharded only over the TP axis.
REPLICA_OVERHEAD = 3.0

# Default per-device budget for that replica. Matches HBM_PER_CHIP in
# launch/mesh.py (TPU v5e, 16 GiB); kept local so dist never imports upward.
WORKER_REPLICA_BUDGET_BYTES = 16 * 2**30


@dataclass(frozen=True)
class Strategy:
    name: str                      # "flat" | "hierarchical" | "plain"
    upload_axes: Tuple[str, ...]   # manual worker axes (empty for plain)
    grad_axes: Tuple[str, ...]     # axes the global batch is split over
    fsdp_axis: Axis
    data_axis: Axis                # auto data axis inside the worker region
    tp_axis: Axis
    num_workers: int
    stage_axis: Optional[str] = None  # manual pipeline axis (None = no PP)
    pipeline_stages: int = 1       # size of stage_axis (1 = no pipelining)
    microbatches: int = 0          # GPipe microbatches (0 -> pipeline_stages)

    @property
    def uses_shard_map(self) -> bool:
        return bool(self.upload_axes)

    @property
    def pipelined(self) -> bool:
        return self.stage_axis is not None and self.pipeline_stages > 1

    @property
    def worker_axes(self) -> Tuple[str, ...]:
        return tuple(self.upload_axes)

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(self.grad_axes)

    @property
    def membership(self) -> Tuple[bool, Tuple[str, ...], int]:
        """Worker-membership identity: (uses_shard_map, worker_axes, M).

        Two strategies with equal membership address the same worker set, so
        an elastic resize between them carries SASG worker state bit-exactly
        (pure resharding); unequal membership means the per-worker EF/stale
        buffers must be re-initialized (DESIGN.md §5)."""
        return (self.uses_shard_map, self.worker_axes, self.num_workers)

    @property
    def inner_dp(self) -> Optional[str]:
        """The auto data axis inside the worker region, if any."""
        if not self.uses_shard_map or self.data_axis is None:
            return None
        if self.data_axis in self.upload_axes:
            return None
        return self.data_axis if isinstance(self.data_axis, str) else None


def worker_replication_fits(
    params_bytes: Optional[int],
    tp_size: int,
    budget_bytes: int = WORKER_REPLICA_BUDGET_BYTES,
) -> bool:
    """Can one SASG worker replica live beside its TP shard? (<= is a fit:
    the budget is the per-device ceiling, so the boundary value still fits.)
    """
    if params_bytes is None:
        return True
    return REPLICA_OVERHEAD * params_bytes / max(tp_size, 1) <= budget_bytes


def choose_strategy(
    mesh,
    sasg_enabled: bool = True,
    params_bytes: Optional[int] = None,
    replica_budget_bytes: int = WORKER_REPLICA_BUDGET_BYTES,
    pipeline_stages: int = 1,
    microbatches: int = 0,
    trunk_layers: Optional[int] = None,
) -> Strategy:
    """Pick the execution strategy for a mesh.

    - 3-D pod meshes -> "hierarchical" (pod = worker, TP-only params — the
      documented FSDP-inside-manual-pod workaround);
    - 2-D / 1-D data meshes -> "flat" (each data slice is a worker);
    - SASG disabled, or ``params_bytes`` too large to worker-replicate ->
      "plain" (auto-SPMD DP, FSDP over every data-like axis).

    ``pipeline_stages >= 2`` requests GPipe pipelining over the mesh's
    ``stage`` axis. The request degrades gracefully (module docstring): it is
    dropped when the mesh has no ``stage`` axis, when the model's homogeneous
    trunk depth (``trunk_layers``, when known) does not divide over the stage
    axis, or when the chosen strategy is "plain". The stage-axis size always
    wins over the requested count — stages are physical mesh slices.
    """
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    tp = "model" if "model" in sizes else None
    dp = tuple(a for a in names if a in ("pod", "data"))

    # Carve the stage axis: the knob engages only when the mesh has one.
    # ``trunk_layers`` semantics: None = unknown (caller vouches for the
    # model), 0 = model has no pipelineable trunk, N = trunk depth.
    stage = "stage" if "stage" in sizes and sizes["stage"] > 1 else None
    stages = sizes.get(stage, 1) if stage else 1
    if pipeline_stages <= 1 or stages <= 1:
        stage, stages = None, 1
    elif trunk_layers is not None and (
        trunk_layers <= 0 or trunk_layers % stages != 0
    ):
        # divisibility fallback: keep the mesh, drop the pipelining (the
        # stage axis stays replicated; mirrors the params_bytes fit fallback)
        stage, stages = None, 1

    if not dp:  # degenerate (TP-only) mesh: nothing to carve workers from
        return Strategy("plain", (), (), None, None, tp, 1)

    dp_degree = math.prod(sizes[a] for a in dp)
    # Stage sharding divides the trunk (the bulk of params) over the stage
    # axis, so it joins TP in the worker-replica fit denominator. This is an
    # upper bound on the per-device replica (pre/post-trunk params are not
    # stage-sharded), consistent with REPLICA_OVERHEAD being a cost model.
    fits = worker_replication_fits(
        params_bytes,
        (sizes.get(tp, 1) if tp else 1) * stages,
        replica_budget_bytes,
    )
    if not sasg_enabled or not fits:
        # "plain" never pipelines: pipeline_apply needs the manual shard_map
        # region that plain, by definition, does not open.
        fsdp = dp if len(dp) > 1 else dp[0]
        return Strategy("plain", (), dp, fsdp, fsdp, tp, dp_degree)

    if "pod" in sizes and "data" in sizes:
        # TP-only hierarchical: fsdp_axis must stay None while the XLA SPMD
        # partitioner rejects FSDP inside manual-pod regions
        # (tests/test_known_limits.py::test_fsdp_inside_manual_podaxis...).
        return Strategy(
            "hierarchical", ("pod",), ("pod", "data"), None, "data", tp,
            sizes["pod"], stage, stages, microbatches,
        )

    wa = dp[0]
    return Strategy(
        "flat", (wa,), (wa,), None, None, tp, sizes[wa],
        stage, stages, microbatches,
    )

"""JAX version-compatibility layer.

The framework targets the modern shard_map surface (``jax.shard_map`` with
``axis_names=`` / ``check_vma=`` and ``jax.sharding.AxisType`` meshes) but
must also run on older 0.4.x installs where that surface does not exist and
where the bundled XLA SPMD partitioner CHECK-fails on collectives
(all-gather / ppermute) placed inside *partial-auto* shard_map regions —
the exact failure family pinned in ``tests/test_known_limits.py``.

Importing this module (``repro/__init__.py`` does it for every consumer)
installs three shims, each only when the running JAX lacks the native API:

- ``jax.shard_map``: forwards to ``jax.experimental.shard_map.shard_map``,
  translating ``axis_names`` (manual axes) into the legacy ``auto``
  complement and ``check_vma`` into ``check_rep``. On partitioner-broken
  jaxlibs the auto axes are *degraded to manual*: specs are unchanged, so
  tensors simply stay replicated (instead of TP-sharded) over the former
  auto axes inside the region. Identical numerics, more per-device memory —
  acceptable on the CPU test meshes; real accelerator jobs run new JAX.
- ``jax.lax.axis_size``: the classic ``psum(1, axis)`` idiom (returns a
  static int for a concrete operand).
- ``make_mesh(shape, axes, axis_types=None)`` helper: builds a mesh with
  ``axis_types`` where supported and silently without it where not, so
  launch/test code has one spelling for both JAX generations.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5-era API: AxisType meshes and a fixed partial-auto partitioner
    from jax.sharding import AxisType as _AxisType

    HAS_AXIS_TYPES = True
except ImportError:
    _AxisType = None
    HAS_AXIS_TYPES = False

# Partial-auto shard_map regions (manual worker axes + auto model axis) only
# partition reliably on jaxlibs new enough to ship AxisType; older SPMD
# partitioners hit fatal CHECKs on any non-psum collective inside them
# (spmd_partitioner.cc "IsManualSubgroup" — tests/test_known_limits.py).
PARTIAL_AUTO_SHARD_MAP = HAS_AXIS_TYPES


def make_mesh(axis_shapes, axis_names, axis_types=None, devices=None):
    """Version-portable ``jax.make_mesh``: Auto axis types when available."""
    kwargs = {} if devices is None else {"devices": devices}
    if HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (_AxisType.Auto,) * len(tuple(axis_shapes))
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def _shard_map_shim(
    f,
    mesh=None,
    in_specs=None,
    out_specs=None,
    axis_names=None,
    check_vma=None,
    check_rep=None,
    auto=None,
):
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:
        raise TypeError("shard_map shim requires an explicit mesh")
    all_axes = frozenset(mesh.axis_names)
    manual = frozenset(axis_names) if axis_names is not None else all_axes
    if auto is None:
        auto = all_axes - manual
    check = check_vma if check_vma is not None else check_rep
    if check is None:
        check = True  # both native APIs default their check on
    if auto and not PARTIAL_AUTO_SHARD_MAP:
        # Degrade auto axes to manual replication (see module docstring):
        # specs never mention them, so every tensor is replicated over them
        # inside the region and the body's collectives stay legal. The
        # static replication checker does not model the degrade, so it is
        # forced off here — the one intentional False.
        auto = frozenset()
        check = False
    return _shard_map(
        f,
        mesh,
        in_specs,
        out_specs,
        check_rep=bool(check),
        auto=frozenset(auto),
    )


def _axis_size_shim(axis_name):
    """``lax.axis_size`` fallback: psum of a concrete 1 folds to a static int."""
    if isinstance(axis_name, (tuple, list)):
        size = 1
        for a in axis_name:
            size *= _axis_size_shim(a)
        return size
    return jax.lax.psum(1, axis_name)


def install() -> None:
    """Idempotently install the shims onto the jax namespace."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_shim
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size_shim


install()

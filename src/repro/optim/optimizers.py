"""Self-built optax-style optimizer library (init/update transforms).

The paper's algorithms use plain SGD with the learning rate folded into the
compressed quantity (fold_lr mode applies `params - update` directly, lr=1
here). For beyond-paper composition (fold_lr=False) the exchange output is a
compressed mean gradient that any transform below can consume — e.g. SASG +
Adam is the CADA-style variant.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Tree = Any


class GradientTransformation(NamedTuple):
    init: Callable[[Tree], Any]
    update: Callable[[Tree, Any, Optional[Tree]], tuple]  # (grads, state, params)


def scale_by_lr(lr: float | Callable) -> GradientTransformation:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params=None):
        rate = lr(count) if callable(lr) else lr
        return jax.tree.map(lambda g: g * rate, grads), count + 1

    return GradientTransformation(init, update)


def sgd(lr: float | Callable = 1.0) -> GradientTransformation:
    return scale_by_lr(lr)


def momentum(lr: float | Callable, beta: float = 0.9, nesterov: bool = False) -> GradientTransformation:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        mu = jax.tree.map(
            lambda m, g: beta * m + g.astype(jnp.float32), state["mu"], grads
        )
        if nesterov:
            upd = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), mu, grads)
        else:
            upd = mu
        rate = lr(state["count"]) if callable(lr) else lr
        upd = jax.tree.map(lambda u: u * rate, upd)
        return upd, {"mu": mu, "count": state["count"] + 1}

    return GradientTransformation(init, update)


def adamw(
    lr: float | Callable,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        c = state["count"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** c.astype(jnp.float32)), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** c.astype(jnp.float32)), v)
        rate = lr(state["count"]) if callable(lr) else lr
        upd = jax.tree.map(lambda m_, v_: rate * m_ / (jnp.sqrt(v_) + eps), mh, vh)
        if weight_decay and params is not None:
            upd = jax.tree.map(
                lambda u, p: u + rate * weight_decay * p.astype(jnp.float32), upd, params
            )
        return upd, {"m": m, "v": v, "count": c}

    return GradientTransformation(init, update)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        leaves = jax.tree.leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
        return jax.tree.map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, states, params=None):
        new_states = []
        for t, s in zip(transforms, states):
            grads, ns = t.update(grads, s, params)
            new_states.append(ns)
        return grads, tuple(new_states)

    return GradientTransformation(init, update)


def apply_updates(params: Tree, updates: Tree) -> Tree:
    """params - updates (updates carry the lr sign convention)."""
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) - u).astype(p.dtype),
                        params, updates)

from .optimizers import (
    GradientTransformation,
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    momentum,
    scale_by_lr,
    sgd,
)
from .schedules import constant, cosine_decay, step_decay, warmup_cosine

"""Learning-rate schedules (callables: step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def step_decay(lr: float, boundaries, factor: float = 0.1):
    """Paper's schedule: decay by `factor` at each boundary step."""
    bounds = jnp.asarray(sorted(boundaries), jnp.int32)

    def fn(step):
        n = jnp.sum(step >= bounds)
        return jnp.float32(lr) * jnp.float32(factor) ** n

    return fn


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.float32(lr) * (
            final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        )

    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, jnp.float32(lr) * w, cos(step - warmup))

    return fn

"""RecurrentGemma-9B — Griffin hybrid: RG-LRU + local attention, 2:1
[arXiv:2402.19427]."""
from .base import ModelConfig, RGLRUConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma_9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
    d_ff=12288, vocab_size=256000,
    attn_pattern=("rglru", "rglru", "local"), window=2048,
    rope_theta=10000.0, mlp_variant="geglu",
    rglru=RGLRUConfig(lru_width=4096, d_conv=4),
    source="arXiv:2402.19427",
))

"""InternVL2-2B — InternLM2-1.8B language backbone; InternViT frontend is a
stub (precomputed patch embeddings as prefix tokens) [arXiv:2404.16821]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2_2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=92553,
    frontend="patch_embed",
    attn_pattern=("global",), rope_theta=1000000.0, mlp_variant="swiglu",
    source="arXiv:2404.16821",
))

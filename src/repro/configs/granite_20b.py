"""Granite-20B code — MQA (kv=1) deep decoder [arXiv:2405.04324]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite_20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab_size=49152,
    attn_pattern=("global",), rope_theta=10000.0, mlp_variant="gelu",
    source="arXiv:2405.04324",
))

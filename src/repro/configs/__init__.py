from .base import (ARCH_IDS, PAPER_IDS, SHAPES, ModelConfig, MoEConfig,
                   RGLRUConfig, SSMConfig, ShapeConfig, all_arch_ids,
                   cell_applicable, get_config, register)

"""Mamba2-370M — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2_370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    attn_pattern=("ssd",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    source="arXiv:2405.21060",
))

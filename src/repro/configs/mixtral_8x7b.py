"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral_8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=32000,
    attn_pattern=("swa",), window=4096, rope_theta=1000000.0,
    mlp_variant="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336, capacity_factor=1.25),
    source="arXiv:2401.04088",
))

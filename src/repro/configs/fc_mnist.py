"""Paper's MNIST model: two-layer fully-connected net, 512 hidden units
(paper Section 5.1)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="fc_mnist", family="mlp",
    n_layers=2, d_model=512, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=10,   # num classes
    param_dtype="float32", compute_dtype="float32",
    source="paper §5.1 (MNIST FC-512)",
))

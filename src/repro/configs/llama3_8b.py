"""Llama-3 8B — dense GQA decoder, 128k vocab [arXiv:2407.21783]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3_8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=128256,
    attn_pattern=("global",), rope_theta=500000.0, mlp_variant="swiglu",
    # realistic pipeline config: 8 homogeneous decoder layers per stage
    pipeline_stages=4,
    source="arXiv:2407.21783",
))

"""Paper's CIFAR model class: ResNet18-style CNN (paper Section 5.1). We use a
compact ResNet (3 stages x 2 basic blocks) so CPU simulation of the four
algorithms is tractable; the comparison semantics (rounds/bits to equal
accuracy) are unchanged."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="cnn_cifar", family="cnn",
    n_layers=6, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=10,
    param_dtype="float32", compute_dtype="float32",
    # smoke-sized pipeline config: the two full-width stage-1 blocks are the
    # homogeneous trunk (paper_nets.CNN_TRUNK_DEPTH), one block per stage
    pipeline_stages=2,
    source="paper §5.1 (ResNet18/CIFAR, compacted)",
))

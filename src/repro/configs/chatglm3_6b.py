"""ChatGLM3-6B — dense GQA(kv=2) decoder with 2d (half) RoPE [arXiv:2406.12793]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chatglm3_6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
    d_ff=13696, vocab_size=65024,
    attn_pattern=("global",), rope_theta=10000.0, rope_style="half",
    mlp_variant="swiglu", source="arXiv:2406.12793",
))

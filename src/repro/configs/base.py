"""Config system: model / shape / mesh / run configs and the registry.

Every assigned architecture registers a ``ModelConfig`` here via its
``src/repro/configs/<id>.py`` module; shapes are the four assigned input
shapes. ``reduced()`` derives the CPU-smoke-test variant of any config.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    num_shared_experts: int = 0    # DeepSeek/Kimi-style always-on experts


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyperparameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block hyperparameters."""

    lru_width: int = 0             # 0 -> d_model
    d_conv: int = 4
    block_width_expand: int = 3 // 1  # gating expansion handled in block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm | audio | mlp | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads
    # attention layout
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over layers:
    #   "global" | "swa" | "local" | "rglru" | "ssd"
    window: int = 4096             # swa / local attention window
    rope_theta: float = 10000.0
    rope_style: str = "full"       # "full" | "half" (ChatGLM 2d-RoPE applies to half dims)
    mlp_variant: str = "swiglu"    # "swiglu" | "geglu" | "gelu"
    # submodule configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder
    encoder_layers: int = 0        # >0 -> enc-dec; n_layers is the decoder depth
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None  # None | "patch_embed" | "audio_frames"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # preferred GPipe stage count when the run's mesh carries a stage axis;
    # 1 = no pipelining. Must divide the model's homogeneous trunk depth
    # (choose_strategy degrades the knob when it does not fit the mesh).
    pipeline_stages: int = 1
    # sub-quadratic? (drives long_500k applicability)
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        return all(p in ("swa", "local", "rglru", "ssd") for p in self.attn_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_kind(self, i: int) -> str:
        return self.attn_pattern[i % len(self.attn_pattern)]

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/wiring, tiny dims. The pipeline
        preference is clamped to the reduced depth so the stage knob still
        divides the (now much shallower) trunk."""
        kw = dict(
            n_layers=min(self.n_layers, 2 * max(1, len(self.attn_pattern))),
            pipeline_stages=min(self.pipeline_stages,
                                2 * max(1, len(self.attn_pattern))),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_head=32,
            d_ff=256,
            vocab_size=256,
            window=min(self.window, 64),
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe, num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2), d_expert=64,
            )
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk_size=32)
        if self.rglru:
            kw["rglru"] = replace(self.rglru, lru_width=128)
        if self.encoder_layers:
            kw["encoder_layers"] = 2
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


ARCH_IDS = [
    "llama3_8b",
    "chatglm3_6b",
    "starcoder2_3b",
    "granite_20b",
    "kimi_k2",
    "mixtral_8x7b",
    "recurrentgemma_9b",
    "mamba2_370m",
    "seamless_m4t_v2",
    "internvl2_2b",
]

PAPER_IDS = ["fc_mnist", "cnn_cifar"]

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_")
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{name}")
    return _REGISTRY[name]


def all_arch_ids() -> list[str]:
    return list(ARCH_IDS)


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Is (arch, shape) runnable? Returns (ok, reason-if-skipped).

    DESIGN.md §6: long_500k needs a sub-quadratic mechanism; enc-dec and
    decoder archs all support decode here (no encoder-only archs assigned).
    """
    cfg = get_config(arch)
    shp = SHAPES[shape]
    if shp.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""

"""SeamlessM4T-large-v2 — encoder-decoder multimodal backbone; audio frontend
is a stub (precomputed frame embeddings) [arXiv:2308.11596]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless_m4t_v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=8192, vocab_size=256206,
    encoder_layers=24, frontend="audio_frames",
    attn_pattern=("global",), rope_theta=10000.0, mlp_variant="gelu",
    source="arXiv:2308.11596",
))

"""StarCoder2-3B — dense GQA(kv=2) code model, GELU MLP [arXiv:2402.19173]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2_3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab_size=49152,
    attn_pattern=("global",), rope_theta=100000.0, mlp_variant="gelu",
    source="arXiv:2402.19173",
))

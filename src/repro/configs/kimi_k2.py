"""Kimi K2 1T-A32B — trillion-parameter MoE, 384 experts top-8
(paper-table config) [arXiv:2501.kimi2]."""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="kimi_k2", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=112,
    d_ff=2048, vocab_size=163840,
    attn_pattern=("global",), rope_theta=50000.0, mlp_variant="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048,
                  capacity_factor=1.25, num_shared_experts=1),
    source="arXiv:2501.kimi2",
))

# Pallas TPU kernels for the SASG hot spots. Each subpackage has:
#   <name>.py  — pl.pallas_call + BlockSpec kernel (TPU target)
#   ops.py     — jit'd public wrapper (interpret=True off-TPU)
#   ref.py     — pure-jnp oracle used by the allclose test sweeps

"""Oracle for the SSD kernel: the pure-jnp chunked implementation used by the
model itself."""
from repro.models.ssd import ssd_chunked as ssd_chunked_ref  # noqa: F401

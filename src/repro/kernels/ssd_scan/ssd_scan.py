"""Pallas TPU kernel: Mamba-2 SSD intra-chunk contraction (arXiv:2405.21060).

Per (batch, chunk, head) grid cell, entirely in VMEM:

    cum   = cumsum(da)                       (Q,)
    L     = exp(cum_i - cum_j) . causal      (Q, Q)
    y     = ((C B^T) . L . dt_j) X           (Q, P)   <- MXU matmuls
    state = (B . (exp(cum_Q - cum) dt))^T X  (N, P)   <- chunk's state delta

The O(S/Q) inter-chunk recurrence and the off-diagonal (state) term are tiny
and stay in jnp (ops.py). The quadratic Q x Q work — the hot spot — never
leaves VMEM; HBM traffic is one read of the chunk operands and one write of
y/state, versus the pure-XLA path that materializes the (Q,Q) decay and
score matrices in HBM.

Grid: (B, n_chunks, H); blocks are one chunk x one head; B/C blocks map the
head to its group (GQA-style n_groups sharing).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    da = da_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    b = b_ref[0, 0, :, 0, :].astype(jnp.float32)     # (Q, N)
    c = c_ref[0, 0, :, 0, :].astype(jnp.float32)     # (Q, N)
    q = x.shape[0]

    cum = jnp.cumsum(da)                             # (Q,)
    seg = cum[:, None] - cum[None, :]                # (Q, Q)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(row >= col, jnp.exp(seg), 0.0)

    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # (Q, Q)
    w = cb * lmat * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)      # (Q, P)

    decay = jnp.exp(cum[-1] - cum) * dt                        # (Q,)
    st = jnp.dot((b * decay[:, None]).T, x,
                 preferred_element_type=jnp.float32)           # (N, P)

    y_ref[0, 0, :, 0, :] = y
    st_ref[0, 0, 0, :, :] = st.T                                # (P, N)


def ssd_chunk_pallas(
    x: jax.Array,      # (B, NC, Q, H, P)
    dt: jax.Array,     # (B, NC, Q, H)
    da: jax.Array,     # (B, NC, Q, H)
    b: jax.Array,      # (B, NC, Q, G, N)
    c: jax.Array,      # (B, NC, Q, G, N)
    interpret: bool = False,
):
    bsz, nc, q, h, p = x.shape
    g, n = b.shape[3], b.shape[4]
    rep = h // g
    grid = (bsz, nc, h)
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi // rep, 0)),
            pl.BlockSpec((1, 1, q, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, da, b, c)
    return y, st

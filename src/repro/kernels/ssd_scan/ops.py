"""jit'd wrapper: full chunked SSD through the Pallas intra-chunk kernel,
signature-compatible with the pure-jnp oracle (repro.models.ssd.ssd_chunked)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_chunk_pallas


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)   softplus'd
    a_log: jax.Array,  # (H,)
    b: jax.Array,      # (B, S, G, N)
    c: jax.Array,      # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,
):
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g

    da = (-jnp.exp(a_log))[None, None, :] * dt
    xr = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    br = b.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cr = c.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    dtr = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    dar = da.reshape(bsz, nc, chunk, h).astype(jnp.float32)

    # intra-chunk diagonal + per-chunk state deltas: Pallas kernel
    y_diag, states = ssd_chunk_pallas(xr, dtr, dar, br, cr, interpret=_use_interpret())

    # inter-chunk recurrence + off-diagonal term (tiny; jnp)
    cum = jnp.cumsum(dar, axis=2)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B, nc, H)

    def scan_body(hprev, inp):
        st, dec = inp
        return hprev * dec[..., None, None] + st, hprev

    hinit = (
        jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    hfin, hprevs = jax.lax.scan(
        scan_body, hinit,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)           # (B, nc, H, P, N)

    state_decay = jnp.exp(cum)                         # (B, nc, Q, H)
    ch = jnp.repeat(cr, rep, axis=3)                   # (B, nc, Q, H, N)
    y_off = jnp.einsum("bzqhn,bzhpn,bzqh->bzqhp", ch, hprevs, state_decay)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, hfin

"""Pure-jnp oracle for the block top-k kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_topk_ref(x2d: jax.Array, kb: int) -> tuple[jax.Array, jax.Array]:
    """x2d: (n_blocks, block_size). Returns (values, local indices), matching
    the kernel's iota tie-break (stable: lowest index wins on equal |x|)."""
    mag = jnp.abs(x2d.astype(jnp.float32))
    # lax.top_k is stable (earlier index wins ties), same as the kernel
    _, idx = jax.lax.top_k(mag, kb)
    vals = jnp.take_along_axis(x2d.astype(jnp.float32), idx, axis=1)
    return vals, idx.astype(jnp.int32)

"""jit'd public wrapper for the block top-k kernel: SparsePayload in/out,
matching repro.core.topk.block_topk semantics (used when
CompressorConfig.topk_impl == "kernel")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topk import SparsePayload
from repro.core.types import ceil_div, pad_to_multiple

from .block_topk import block_topk_pallas


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def block_topk(x: jax.Array, k: int, block_size: int = 2048) -> SparsePayload:
    assert x.ndim == 1
    d = x.size
    xp = pad_to_multiple(x.astype(jnp.float32), block_size)
    nb = xp.size // block_size
    kb = min(max(1, ceil_div(int(min(k, d)), nb)), block_size)
    x2d = xp.reshape(nb, block_size)
    # mask the padded tail so it is never selected
    pos = jnp.arange(nb * block_size).reshape(nb, block_size)
    x2d = jnp.where(pos < d, x2d, 0.0)
    vals, idx = block_topk_pallas(x2d, kb, interpret=_use_interpret())
    flat_idx = idx + (jnp.arange(nb, dtype=jnp.int32) * block_size)[:, None]
    in_range = flat_idx < d
    vals = jnp.where(in_range, vals, 0.0)
    flat_idx = jnp.where(in_range, flat_idx, d - 1)
    return SparsePayload(
        values=vals.reshape(-1), indices=flat_idx.reshape(-1), size=d
    )

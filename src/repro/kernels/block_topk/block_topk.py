"""Pallas TPU kernel: block-local top-k selection (paper Definition 1,
TPU-native block granularity — DESIGN.md §2).

The flat vector is viewed as (n_blocks, block_size); each grid step loads a
tile of TILE_BLOCKS rows into VMEM and selects the k_b largest-|x| entries
per row with an iterative argmax (k_b is small: ~1% of block_size). All inner
ops are rank-preserving vector ops (max/compare/select/iota) — no gathers —
so the kernel maps onto the VPU; HBM traffic is exactly one read of x plus
the (tiny) value/index outputs, i.e. the op is memory-bound at 1x read.

Grid/BlockSpec: grid=(n_blocks // TILE_BLOCKS,), x tile (TILE_BLOCKS, BS) in
VMEM; outputs tiled (TILE_BLOCKS, KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_tile_kernel(x_ref, vals_ref, idx_ref, *, kb: int):
    x = x_ref[...].astype(jnp.float32)          # (TB, BS)
    tb, bs = x.shape
    mag = jnp.abs(x)
    col = jax.lax.broadcasted_iota(jnp.int32, (tb, bs), 1)

    def body(i, carry):
        mag_c = carry
        mx = jnp.max(mag_c, axis=1, keepdims=True)             # (TB, 1)
        # first column achieving the max (iota tie-break)
        is_max = mag_c == mx
        first = jnp.min(jnp.where(is_max, col, bs), axis=1, keepdims=True)
        sel = col == first                                      # (TB, BS) one-hot
        val = jnp.sum(jnp.where(sel, x, 0.0), axis=1)           # (TB,)
        vals_ref[:, i] = val
        idx_ref[:, i] = first[:, 0]
        return jnp.where(sel, -jnp.inf, mag_c)

    jax.lax.fori_loop(0, kb, body, mag)


def block_topk_pallas(
    x2d: jax.Array,          # (n_blocks, block_size), already padded
    kb: int,
    tile_blocks: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    nb, bs = x2d.shape
    tile_blocks = min(tile_blocks, nb)
    while nb % tile_blocks:
        tile_blocks -= 1
    grid = (nb // tile_blocks,)
    kernel = functools.partial(_topk_tile_kernel, kb=kb)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_blocks, bs), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile_blocks, kb), lambda i: (i, 0)),
            pl.BlockSpec((tile_blocks, kb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, kb), jnp.float32),
            jax.ShapeDtypeStruct((nb, kb), jnp.int32),
        ],
        interpret=interpret,
    )(x2d)
    return vals, idx

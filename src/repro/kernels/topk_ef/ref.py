"""Pure-jnp oracle for the fused EF + block top-k kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ef_ref(grad2d: jax.Array, err2d: jax.Array, lr, kb: int):
    """Returns (new_err, values, local_indices) with the same semantics as
    the kernel: g = lr*grad + err; per-row top-kb by |g| (stable ties);
    new_err zeros the selected coordinates."""
    g = lr * grad2d.astype(jnp.float32) + err2d.astype(jnp.float32)
    mag = jnp.abs(g)
    _, idx = jax.lax.top_k(mag, kb)                       # stable tie-break
    vals = jnp.take_along_axis(g, idx, axis=1)
    onehot = jax.nn.one_hot(idx, g.shape[1], dtype=bool)  # (nb, kb, bs)
    taken = onehot.any(axis=1)
    new_err = jnp.where(taken, 0.0, g)
    return new_err, vals, idx.astype(jnp.int32)

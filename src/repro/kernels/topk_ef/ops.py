"""jit'd wrapper: fused EF + block top-k over a flat vector, producing a
SparsePayload and the updated error buffer — drop-in for the unfused
(compress + densify-subtract) path in repro.core.compressors."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topk import SparsePayload
from repro.core.types import ceil_div, pad_to_multiple

from .topk_ef import topk_ef_pallas


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tile_rows(n_rows: int) -> int:
    # Interpret mode executes the grid sequentially in the XLA interpreter,
    # so one grid step over all rows is fastest on CPU; real TPU keeps the
    # default 8-row tiles (VMEM-sized).
    return n_rows if _use_interpret() else 8


def block_topk(x: jax.Array, k: int, block_size: int = 2048) -> SparsePayload:
    """Plain block top-k through the fused kernel (zero error, lr=1)."""
    p, _ = topk_ef(x, jnp.zeros_like(x, dtype=jnp.float32), jnp.float32(1.0),
                   k, block_size)
    return p


def blocked_topk_ef(
    grad_blocked: jax.Array,   # (*lead, nbc, block_c) — the per-shard view
    err_blocked: jax.Array,    # same shape, EF accumulator
    kb: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF + top-kb on an already shard-aligned blocked view.

    The per-shard transport path: the caller has laid the leaf out as
    ``(*lead, nbc, block_c)`` with block boundaries aligned to the sharded
    axis (``repro.core.topk.blocked_view_shape``), and has folded the
    learning rate into ``grad_blocked`` already (lr=1 here). Returns
    ``(values, indices, new_err)`` with values/indices shaped
    ``(*lead, nbc, kb)`` and block-LOCAL int32 indices — bit-identical to
    the unfused ``blocked_topk`` + scatter-subtract reference (same
    iterative masked-argmax, same first-index tie-break).
    """
    assert grad_blocked.shape == err_blocked.shape
    lead = grad_blocked.shape[:-1]
    bc = grad_blocked.shape[-1]
    rows = 1
    for d in lead:
        rows *= d
    g2 = grad_blocked.reshape(rows, bc).astype(jnp.float32)
    e2 = err_blocked.reshape(rows, bc).astype(jnp.float32)
    new_err, vals, idx = topk_ef_pallas(
        g2, e2, jnp.float32(1.0), kb,
        tile_blocks=_tile_rows(rows), interpret=_use_interpret(),
    )
    return (
        vals.reshape(lead + (kb,)),
        idx.reshape(lead + (kb,)),
        new_err.reshape(grad_blocked.shape),
    )


def topk_ef(
    grad: jax.Array,        # (d,) flat gradient
    err: jax.Array,         # (d,) fp32 error buffer
    lr: jax.Array,          # scalar
    k: int,
    block_size: int = 2048,
) -> tuple[SparsePayload, jax.Array]:
    assert grad.ndim == 1 and err.shape == grad.shape
    d = grad.size
    gp = pad_to_multiple(grad.astype(jnp.float32), block_size)
    ep = pad_to_multiple(err.astype(jnp.float32), block_size)
    nb = gp.size // block_size
    kb = min(max(1, ceil_div(int(min(k, d)), nb)), block_size)
    g2, e2 = gp.reshape(nb, block_size), ep.reshape(nb, block_size)
    # zero the padded tail so it is never selected
    pos = jnp.arange(nb * block_size).reshape(nb, block_size)
    g2 = jnp.where(pos < d, g2, 0.0)
    e2 = jnp.where(pos < d, e2, 0.0)
    new_err, vals, idx = topk_ef_pallas(
        g2, e2, lr, kb, tile_blocks=_tile_rows(nb), interpret=_use_interpret()
    )
    flat_idx = idx + (jnp.arange(nb, dtype=jnp.int32) * block_size)[:, None]
    in_range = flat_idx < d
    vals = jnp.where(in_range, vals, 0.0)
    flat_idx = jnp.where(in_range, flat_idx, d - 1)
    payload = SparsePayload(vals.reshape(-1), flat_idx.reshape(-1), d)
    return payload, new_err.reshape(-1)[:d]

"""Pallas TPU kernel: fused error-feedback + block top-k + residual update —
the SASG hot loop (paper Algorithm 1, lines 4/7-8).

Unfused, the per-step compression path reads/writes HBM four times over the
model dimension d:

    g = lr*grad + e     (read grad, read e, write g)
    topk(g)             (read g)
    e' = g - T_k(g)     (read g, write e')

Fused, each d-element flows HBM->VMEM once and back once:

    read grad, read e  ->  compute g, per-block top-k, e'  ->  write e', (v,i)

i.e. 2 reads + 1 write of d floats + O(k) outputs versus 4 reads + 2 writes —
a ~2x cut on the memory-bound term of the compression stage. Selection uses
the same iterative masked-argmax as block_topk (VPU-only, no gathers).

Grid/BlockSpec: grid=(n_blocks/TILE,), tiles (TILE, BS) of grad and err in
VMEM; outputs: err' tile (TILE, BS), values/indices tiles (TILE, KB); lr is
a scalar-prefetch style (1,1) VMEM operand broadcast by indexing map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_ef_kernel(lr_ref, grad_ref, err_ref, newerr_ref, vals_ref, idx_ref,
                    *, kb: int):
    lr = lr_ref[0, 0]
    g = lr * grad_ref[...].astype(jnp.float32) + err_ref[...].astype(jnp.float32)
    tb, bs = g.shape
    mag = jnp.abs(g)
    col = jax.lax.broadcasted_iota(jnp.int32, (tb, bs), 1)

    def body(i, carry):
        mag_c, taken = carry
        mx = jnp.max(mag_c, axis=1, keepdims=True)
        is_max = mag_c == mx
        first = jnp.min(jnp.where(is_max, col, bs), axis=1, keepdims=True)
        sel = col == first
        vals_ref[:, i] = jnp.sum(jnp.where(sel, g, 0.0), axis=1)
        idx_ref[:, i] = first[:, 0]
        return jnp.where(sel, -jnp.inf, mag_c), taken | sel

    _, taken = jax.lax.fori_loop(
        0, kb, body, (mag, jnp.zeros_like(mag, dtype=bool))
    )
    newerr_ref[...] = jnp.where(taken, 0.0, g)


def topk_ef_pallas(
    grad2d: jax.Array,       # (n_blocks, block_size)
    err2d: jax.Array,        # (n_blocks, block_size) fp32
    lr: jax.Array,           # scalar
    kb: int,
    tile_blocks: int = 8,
    interpret: bool = False,
):
    nb, bs = grad2d.shape
    tile_blocks = min(tile_blocks, nb)
    while nb % tile_blocks:
        tile_blocks -= 1
    grid = (nb // tile_blocks,)
    kernel = functools.partial(_topk_ef_kernel, kb=kb)
    newerr, vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),                 # lr scalar
            pl.BlockSpec((tile_blocks, bs), lambda i: (i, 0)),       # grad
            pl.BlockSpec((tile_blocks, bs), lambda i: (i, 0)),       # err
        ],
        out_specs=[
            pl.BlockSpec((tile_blocks, bs), lambda i: (i, 0)),       # err'
            pl.BlockSpec((tile_blocks, kb), lambda i: (i, 0)),       # values
            pl.BlockSpec((tile_blocks, kb), lambda i: (i, 0)),       # indices
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), jnp.float32),
            jax.ShapeDtypeStruct((nb, kb), jnp.float32),
            jax.ShapeDtypeStruct((nb, kb), jnp.int32),
        ],
        interpret=interpret,
    )(lr.reshape(1, 1).astype(jnp.float32), grad2d, err2d)
    return newerr, vals, idx

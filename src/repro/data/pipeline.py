"""Host-side data pipeline: background prefetch + device placement.

In a multi-host deployment each host feeds its addressable shard of the
global batch (`jax.make_array_from_process_local_data`); in this single-host
container the loader materializes the global batch and lets the sharding
place it. Prefetch depth decouples host data generation from device step
time (straggler hiding on the input side)."""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax


class ShardedLoader:
    def __init__(
        self,
        source: Iterator[dict],
        shardings: Optional[dict] = None,
        prefetch: int = 2,
    ):
        self.source = source
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch: dict):
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {
            k: jax.device_put(v, self.shardings.get(k)) for k, v in batch.items()
        }

    def _worker(self):
        try:
            for batch in self.source:
                if self._stop.is_set():
                    return
                self._q.put(self._place(batch))
        except Exception as e:  # surface loader failures to the consumer
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

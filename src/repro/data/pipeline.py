"""Host-side data pipeline: background prefetch + device placement.

In a multi-host deployment each host feeds its addressable shard of the
global batch (`jax.make_array_from_process_local_data`); in this single-host
container the loader materializes the global batch and lets the sharding
place it. Prefetch depth decouples host data generation from device step
time (straggler hiding on the input side).

Failure contract: a worker-thread exception is delivered to the consumer as
a poisoned sentinel — the next ``__next__`` re-raises the original exception
(never a silent end-of-stream); source exhaustion delivers an end sentinel
that raises ``StopIteration``. ``close()`` unblocks and joins the prefetch
thread so no daemon thread outlives the consumer.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional


class _Poison:
    """Sentinel carrying the prefetch worker's exception to the consumer."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


_END = object()  # source exhausted: StopIteration at the consumer

_PUT_POLL_S = 0.1  # worker put() poll so close() can always unblock it


class ShardedLoader:
    def __init__(
        self,
        source: Iterator[dict],
        shardings: Optional[dict] = None,
        prefetch: int = 2,
    ):
        self.source = source
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch: dict):
        import jax

        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {
            k: jax.device_put(v, self.shardings.get(k)) for k, v in batch.items()
        }

    def _put(self, item) -> bool:
        """Bounded-queue put that stays interruptible by close()."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=_PUT_POLL_S)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        try:
            for batch in self.source:
                if self._stop.is_set():
                    return
                if not self._put(self._place(batch)):
                    return
        except Exception as e:  # poisoned sentinel: consumer re-raises
            self._put(_Poison(e))
        else:
            self._put(_END)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is _END:
            raise StopIteration
        if isinstance(item, _Poison):
            raise item.exc
        # legacy contract: a bare Exception instance in the queue also raises
        if isinstance(item, Exception):
            raise item
        return item

    def close(self, timeout: float = 5.0):
        """Stop prefetching, drain the queue, and join the worker thread."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

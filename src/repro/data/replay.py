"""Deterministically replayable, step-indexed data streams (DESIGN.md §5).

The elastic Trainer's recovery contract requires that batch ``t`` is a pure
function of ``(seed, t)``: after any checkpoint restore or in-run mesh
resize, the run must consume exactly the batch sequence an uninterrupted run
would have consumed — zero skipped, zero duplicated. Python generators
cannot provide that (they are consumed destructively; a failed step loses
its batch forever), so the Trainer-facing source here is a
:class:`ReplayableStream`: a step-indexed batch function behind a seekable
cursor. ``Trainer`` calls ``seek(step)`` after every restore/resize, and the
chaos suite asserts replay batch-by-batch via :func:`batch_fingerprint`.

Per-step randomness derives from ``np.random.default_rng((seed, tag, step))``
(a SeedSequence entropy tuple), so ``batch_at(t)`` never depends on how many
batches were drawn before it.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Iterator

import numpy as np

# domain-separation tags so a token stream and a classification stream with
# the same seed never alias each other's per-step rngs
_TOKEN_TAG = 0x70CE
_CLASS_TAG = 0xC1A5


class ReplayableStream:
    """Step-indexed batch source with a seekable cursor.

    ``batch_fn(step) -> dict`` must be pure (same step, same batch). The
    iterator protocol yields ``batch_fn(cursor)`` and advances; ``seek``
    rewinds (or fast-forwards) the cursor so the Trainer can replay from a
    restored checkpoint step.
    """

    def __init__(self, batch_fn: Callable[[int], dict], start: int = 0):
        self._fn = batch_fn
        self._cursor = int(start)

    @property
    def cursor(self) -> int:
        return self._cursor

    def seek(self, step: int) -> None:
        if step < 0:
            raise ValueError(f"cannot seek to negative step {step}")
        self._cursor = int(step)

    def batch_at(self, step: int) -> dict:
        """The batch consumed at training step ``step`` (pure; cursor-free)."""
        return self._fn(int(step))

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = self._fn(self._cursor)
        self._cursor += 1
        return batch


def indexed_token_stream(
    vocab: int, batch: int, seq: int, seed: int = 0,
    bigram_order: float = 0.8,
) -> ReplayableStream:
    """Replayable counterpart of ``synthetic.token_stream``: same planted
    bigram structure (one fixed successor table per seed), but batch ``t`` is
    generated from an rng keyed on ``(seed, t)`` instead of a shared
    generator, so it is identical across any resize/restore history."""
    trans = np.random.default_rng(seed).permutation(vocab)

    def batch_fn(step: int) -> dict:
        rng = np.random.default_rng((seed, _TOKEN_TAG, step))
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        follow = rng.random(size=(batch, seq)) < bigram_order
        rand_next = rng.integers(0, vocab, size=(batch, seq))
        for t in range(seq):
            nxt = trans[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, rand_next[:, t])
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}

    return ReplayableStream(batch_fn)


def indexed_classification_stream(
    x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
) -> ReplayableStream:
    """Replayable counterpart of ``synthetic.classification_stream``."""
    n = x.shape[0]

    def batch_fn(step: int) -> dict:
        rng = np.random.default_rng((seed, _CLASS_TAG, step))
        idx = rng.integers(0, n, size=batch)
        return {"x": x[idx], "labels": y[idx]}

    return ReplayableStream(batch_fn)


def batch_fingerprint(batch: dict) -> str:
    """Content hash of one batch (key-order independent). The chaos tests
    compare per-step fingerprints between a faulted run and an uninterrupted
    one to assert zero skipped / duplicated batches."""
    h = hashlib.md5()
    for k in sorted(batch):
        v = np.asarray(batch[k])
        h.update(k.encode())
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(np.ascontiguousarray(v).tobytes())
    return h.hexdigest()

from .synthetic import (
    classification_stream,
    make_batch,
    synthetic_classification,
    token_stream,
)
from .pipeline import ShardedLoader
from .replay import (
    ReplayableStream,
    batch_fingerprint,
    indexed_classification_stream,
    indexed_token_stream,
)

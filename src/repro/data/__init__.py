from .synthetic import (
    classification_stream,
    make_batch,
    synthetic_classification,
    token_stream,
)
from .pipeline import ShardedLoader

"""Deterministic synthetic data sources.

The container is offline; all experiments run on synthetic-but-structured
data: token streams with a planted bigram structure (so LMs have learnable
signal and loss curves are meaningful), and Gaussian-mixture classification
sets shaped like MNIST/CIFAR for the paper-reproduction benchmarks.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """One concrete global batch matching `input_specs` (host numpy)."""
    rng = np.random.default_rng(seed)
    b, s = shape.global_batch, shape.seq_len
    if cfg.family in ("mlp", "cnn"):
        x = rng.normal(size=(b, 28, 28, 1) if cfg.family == "mlp" else (b, 32, 32, 3))
        return {
            "x": x.astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size, size=(b,), dtype=np.int32),
        }
    if cfg.is_encdec:
        ss = s // 2
        return {
            "frames": rng.normal(size=(b, ss, cfg.d_model)).astype(np.float32),
            "tokens": rng.integers(0, cfg.vocab_size, size=(b, ss), dtype=np.int32),
            "labels": rng.integers(0, cfg.vocab_size, size=(b, ss), dtype=np.int32),
        }
    if cfg.frontend == "patch_embed":
        np_tok = 256 if s > 256 else s // 4
        st = s - np_tok
        return {
            "tokens": rng.integers(0, cfg.vocab_size, size=(b, st), dtype=np.int32),
            "patch_embeds": rng.normal(size=(b, np_tok, cfg.d_model)).astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size, size=(b, st), dtype=np.int32),
        }
    return {
        "tokens": rng.integers(0, cfg.vocab_size, size=(b, s), dtype=np.int32),
        "labels": rng.integers(0, cfg.vocab_size, size=(b, s), dtype=np.int32),
    }


def token_stream(
    vocab: int, batch: int, seq: int, seed: int = 0,
    bigram_order: float = 0.8,
) -> Iterator[dict]:
    """Infinite stream of (tokens, labels) with a planted bigram transition
    structure: next token is T[cur] with prob `bigram_order`, else uniform.
    An LM can reduce loss by learning T — giving meaningful training curves
    on a fully offline box."""
    rng = np.random.default_rng(seed)
    trans = rng.permutation(vocab)  # deterministic bigram successor table

    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        follow = rng.random(size=(batch, seq)) < bigram_order
        rand_next = rng.integers(0, vocab, size=(batch, seq))
        for t in range(seq):
            nxt = trans[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, rand_next[:, t])
        yield {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}


def synthetic_classification(
    n: int, num_classes: int, image_shape=(28, 28, 1), seed: int = 0,
    noise: float = 0.35,
):
    """Gaussian-mixture images: class c has a fixed random template + noise.
    Linear-separable-ish, so FC/CNN accuracy curves behave like MNIST's."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(num_classes,) + image_shape).astype(np.float32)
    labels = rng.integers(0, num_classes, size=n, dtype=np.int32)
    x = templates[labels] + noise * rng.normal(size=(n,) + image_shape).astype(np.float32)
    return x.astype(np.float32), labels


def classification_stream(
    x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        idx = rng.integers(0, n, size=batch)
        yield {"x": x[idx], "labels": y[idx]}

"""Paged KV cache plumbing for the continuous-batching serve engine.

DESIGN.md §9. The cache itself is built by ``Model.init_paged_cache`` (block
pools per global-attention layer + one per-sequence block table); this module
owns everything around it:

- :class:`BlockAllocator` — the host-side free list. Blocks are allocated
  up front at admission (prompt + max_new tokens worth), so a request that
  is admitted can never deadlock on blocks mid-flight, and the pool
  high-water mark equals the tokens actually in flight.
- the cache *codec*: cache blocks are quantized **on write** by storing the
  pools at an :class:`~repro.comm.transport.ActivationLayout` wire dtype
  (``k_ratio=0`` — a pure dtype cast, the same bit-reduction lever the
  gradient exchange and the activation ring already use). The identity
  layout (wire dtype == compute dtype) is bit-exact vs the dense cache;
  narrower dtypes are gated by a parity-tolerance test.
- jit-able slot lifecycle ops: :func:`select_slots` (commit only the active
  slots of a tick), :func:`reset_slots` (recycle a slot for a new request),
  :func:`release_blocks` (return freed blocks with their position rows
  poisoned so a recycled block never exposes the previous occupant).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.comm.bits import kv_cache_bits_per_token
from repro.comm.transport import ActivationLayout
from repro.configs.base import ModelConfig

# leaves owned by the paged pools / block table: never batch-masked (their
# frozen-slot writes were already dropped at the scatter via OOB indices)
_POOL_KEYS = ("pk", "pv", "ppos", "bt")
# recurrent per-slot states (RG-LRU / SSD rows) that must be zeroed on reuse
_RECURRENT_KEYS = ("h", "conv")


def cache_layout(cfg: ModelConfig, wire_dtype: Optional[str] = None) -> ActivationLayout:
    """The cache write codec: an ActivationLayout with ``k_ratio=0``.

    ``encode`` degenerates to the dtype cast the pool writes apply, so the
    codec and the stored dtype cannot drift apart; ``payload_bits`` prices
    the stored bytes. ``None`` selects the model compute dtype (identity)."""
    wd = wire_dtype or str(jnp.dtype(cfg.compute_dtype))
    return ActivationLayout(wire_dtype=wd, k_ratio=0.0)


def paged_bits_per_token(cfg: ModelConfig, layout: ActivationLayout) -> float:
    """Stored bits per token across this config's paged layers."""
    n_paged = sum(
        1 for i in range(cfg.n_layers)
        if cfg.attn_pattern[i % len(cfg.attn_pattern)] == "global"
    )
    return kv_cache_bits_per_token(
        n_paged, cfg.n_kv_heads, cfg.head_dim, layout.wire_dtype
    )


class BlockAllocator:
    """Host-side free-list allocator over a fixed pool of cache blocks.

    Block ids index every paged layer's pool identically (one table, N
    pools). Tracks the pool high-water mark for the memory claims in
    BENCH_serve.json."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, -1, -1))  # pop() -> low ids first
        self.high_water = 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n: int) -> bool:
        return len(self._free) >= n

    def allocate(self, n: int) -> List[int]:
        if not self.can_allocate(n):
            raise RuntimeError(
                f"paged cache exhausted: want {n} blocks, {len(self._free)} free"
            )
        ids = [self._free.pop() for _ in range(n)]
        self.high_water = max(self.high_water, self.used_blocks)
        return ids

    def free(self, ids: List[int]) -> None:
        for i in ids:
            assert 0 <= i < self.num_blocks and i not in self._free, i
            self._free.append(i)


def _keys_of(path) -> list:
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]


def _batch_axis(keys: list) -> int:
    # stacked leading layer dims: "unit" (LM scan) / "self"/"xkv" (encdec)
    return 1 if any(k in ("unit", "self", "xkv") for k in keys) else 0


def select_slots(new_cache, old_cache, active: jax.Array):
    """Per-slot tick commit: recurrent-state rows of ``new_cache`` where
    ``active``, the old rows otherwise. KV leaves (dense rings, pools, pos
    tables) pass through unchanged — frozen slots never reached them, their
    scatters were dropped at OOB indices — but RG-LRU/SSD states update
    unconditionally inside the forward, so a frozen slot's padding tokens
    would corrupt its recurrence without this select."""

    def leaf(path, n, o):
        keys = _keys_of(path)
        if keys[-1] not in _RECURRENT_KEYS:
            return n
        ax = _batch_axis(keys)
        m = active.reshape((1,) * ax + active.shape + (1,) * (n.ndim - ax - 1))
        return jnp.where(m, n, o)

    return jax.tree_util.tree_map_with_path(leaf, new_cache, old_cache)


def reset_slots(cache, mask: jax.Array):
    """Recycle slots for new occupants: position rows -> -1 (no stale reads
    — the shared-global-pos regression this engine exists to fix), recurrent
    rows -> 0 (a fresh sequence start). Dense K/V values become unreachable
    once their positions are negative and need no zeroing."""

    def leaf(path, x):
        keys = _keys_of(path)
        key = keys[-1]
        if key in _POOL_KEYS:
            return x
        ax = _batch_axis(keys)
        m = mask.reshape((1,) * ax + mask.shape + (1,) * (x.ndim - ax - 1))
        if key == "pos":
            return jnp.where(m, jnp.full_like(x, -1), x)
        if key in _RECURRENT_KEYS:
            return jnp.where(m, jnp.zeros_like(x), x)
        return x

    return jax.tree_util.tree_map_with_path(leaf, cache)


def release_blocks(cache, block_ids: jax.Array):
    """Poison the position rows of freed blocks (``block_ids`` padded with
    OOB ids) so a recycled block never exposes the previous sequence's
    positions. Values may remain in the pool: they are unreachable once
    ``ppos < 0`` and are overwritten before the positions go live again."""

    def leaf(path, x):
        if _keys_of(path)[-1] == "ppos":
            # stacked (n_units, NB, bs) or flat (NB, bs): poison on the NB dim
            if x.ndim == 3:
                return x.at[:, block_ids].set(-1, mode="drop")
            return x.at[block_ids].set(-1, mode="drop")
        return x

    return jax.tree_util.tree_map_with_path(leaf, cache)


def cache_bytes(cache) -> int:
    """Total device bytes held by a decode cache tree."""
    return sum(
        x.size * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(cache)
    )

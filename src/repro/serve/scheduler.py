"""Slot scheduler for the continuous-batching serve engine.

Request lifecycle (DESIGN.md §9): ``queued -> prefill -> decode -> done``.
Admission is all-or-nothing — a request enters a slot only when a slot is
free AND (paged mode) its full block budget ``ceil((prompt + max_new - 1)
/ block_size)`` is allocatable, so an admitted request can never stall
mid-flight on cache capacity.

Every engine tick has a *width* w (tokens fed per active slot):

- ``w == 1`` — a decode tick. Every slot with a pending token participates:
  decode slots feed their last sampled token, prefill slots feed their next
  prompt token.
- ``w > 1`` — a chunked-prefill tick. Only prefill slots with at least w
  prompt tokens remaining participate (a partial chunk would scatter
  padding into live cache positions); decode slots are frozen for the tick
  (position -1: the model drops their writes and masks their reads).

Chunked prefill interleaves with decoding by fairness flag: after any
chunked tick, the next tick is forced to width 1 whenever a decode slot is
waiting, so admitting a long prompt can at most double the latency between
two decode tokens rather than stalling them for the whole prefill.

A prefill slot whose remaining prompt is exactly the tick width completes
prefill in that tick and consumes the tick's sample (the last prompt
token's logits ARE the first generated token's distribution) — prefill
needs no extra "first decode" tick.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .paged_cache import BlockAllocator

QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16


@dataclass
class SlotEntry:
    req: Request
    state: str = PREFILL
    n_fed: int = 0                # tokens committed to the cache so far
    generated: List[int] = field(default_factory=list)
    blocks: List[int] = field(default_factory=list)

    @property
    def prompt_remaining(self) -> int:
        return len(self.req.prompt) - self.n_fed


@dataclass
class TickPlan:
    width: int
    tokens: np.ndarray            # (B, width) int32, zeros on frozen slots
    pos: np.ndarray               # (B,) int32 base positions, -1 frozen
    active: List[int]             # slot indices participating this tick
    samplers: List[int]           # slots consuming sampled[slot] this tick


class Scheduler:
    """Host-side request queue + slot state machine.

    Owns no device state: the engine passes its plans to the model and
    feeds the sampled tokens back through :meth:`apply`."""

    def __init__(
        self,
        batch_size: int,
        max_seq: int,
        widths: Sequence[int] = (1,),
        allocator: Optional[BlockAllocator] = None,
    ):
        self.batch = batch_size
        self.max_seq = max_seq
        self.widths = tuple(sorted(set(int(w) for w in widths), reverse=True))
        assert self.widths and self.widths[-1] == 1, self.widths
        self.allocator = allocator
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[SlotEntry]] = [None] * batch_size
        self._decode_due = False

    # -- admission -----------------------------------------------------

    def cache_tokens(self, req: Request) -> int:
        """Cache positions a request occupies: the final sampled token is
        returned but never fed, so it needs no slot."""
        return len(req.prompt) + req.max_new_tokens - 1

    def validate(self, req: Request) -> None:
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: empty prompt or max_new < 1")
        need = self.cache_tokens(req)
        if need > self.max_seq:
            raise ValueError(
                f"request {req.uid}: needs {need} cache tokens > max_seq "
                f"{self.max_seq} — would silently overwrite its own cache"
            )

    def submit(self, req: Request) -> None:
        self.validate(req)
        self.queue.append(req)

    def admit(self) -> List[int]:
        """Move queued requests into free slots (and, paged, allocate their
        full block budget). Returns the slot indices admitted this call —
        the engine must reset those cache rows before the next tick."""
        admitted = []
        for i in range(self.batch):
            if not self.queue or self.slots[i] is not None:
                continue
            req = self.queue[0]
            blocks: List[int] = []
            if self.allocator is not None:
                need = self.allocator.blocks_for(self.cache_tokens(req))
                if not self.allocator.can_allocate(need):
                    break  # FIFO: don't let small requests starve the head
                blocks = self.allocator.allocate(need)
            self.queue.popleft()
            self.slots[i] = SlotEntry(req=req, blocks=blocks)
            admitted.append(i)
        return admitted

    # -- planning ------------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def n_pending(self) -> int:
        return self.n_active + len(self.queue)

    def pending_uids(self) -> List[int]:
        return [s.req.uid for s in self.slots if s is not None] + [
            r.uid for r in self.queue
        ]

    def _pick_width(self) -> int:
        any_decode = any(s and s.state == DECODE for s in self.slots)
        if self._decode_due and any_decode:
            return 1
        for w in self.widths:
            if w == 1:
                break
            if any(
                s and s.state == PREFILL and s.prompt_remaining >= w
                for s in self.slots
            ):
                return w
        return 1

    def plan(self) -> Optional[TickPlan]:
        if self.n_active == 0:
            return None
        w = self._pick_width()
        tokens = np.zeros((self.batch, w), np.int32)
        pos = np.full((self.batch,), -1, np.int32)
        active: List[int] = []
        samplers: List[int] = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            if s.state == PREFILL:
                if s.prompt_remaining < w:
                    continue  # frozen: partial chunks don't participate
                tokens[i] = s.req.prompt[s.n_fed : s.n_fed + w]
                pos[i] = s.n_fed
                active.append(i)
                if s.prompt_remaining == w:
                    samplers.append(i)
            else:  # DECODE: one pending token, only fits a width-1 tick
                if w != 1:
                    continue
                tokens[i, 0] = s.generated[-1]
                pos[i] = s.n_fed
                active.append(i)
                samplers.append(i)
        # a chunked tick skipped the decode slots: they go first next tick
        self._decode_due = w > 1
        return TickPlan(width=w, tokens=tokens, pos=pos,
                        active=active, samplers=samplers)

    # -- commit --------------------------------------------------------

    def apply(
        self, plan: TickPlan, sampled: np.ndarray
    ) -> Tuple[List[dict], List[int]]:
        """Advance slot state by one executed tick. ``sampled`` is the
        (B,)-shaped greedy sample of the tick's last-column logits. Returns
        ``(completions, freed_blocks)``; completed slots are already freed
        (the engine resets their cache rows on the next admission)."""
        completions: List[dict] = []
        freed: List[int] = []
        for i in plan.active:
            s = self.slots[i]
            s.n_fed += plan.width if s.state == PREFILL else 1
            if i in plan.samplers:
                s.state = DECODE
                s.generated.append(int(sampled[i]))
                if len(s.generated) >= s.req.max_new_tokens:
                    completions.append(
                        {"uid": s.req.uid, "tokens": list(s.generated)}
                    )
                    freed.extend(s.blocks)
                    self.slots[i] = None
        return completions, freed

from .engine import BatchedServer, BuiltServe, Request, build_serve

from .engine import BatchedServer, BuiltServe, Request, build_serve
from .paged_cache import (
    BlockAllocator,
    cache_bytes,
    cache_layout,
    paged_bits_per_token,
    release_blocks,
    reset_slots,
    select_slots,
)
from .scheduler import Scheduler, SlotEntry, TickPlan

__all__ = [
    "BatchedServer",
    "BlockAllocator",
    "BuiltServe",
    "Request",
    "Scheduler",
    "SlotEntry",
    "TickPlan",
    "build_serve",
    "cache_bytes",
    "cache_layout",
    "paged_bits_per_token",
    "release_blocks",
    "reset_slots",
    "select_slots",
]

"""Serving engine: continuous batching over ``decode_step``.

Serving uses no SASG (inference has no gradient traffic); params are FSDP x
TP sharded like training so multi-hundred-GB models fit. ``decode_step`` is
the unit the decode_32k / long_500k dry-run shapes lower: a (B, W) token
chunk per tick against per-slot KV caches (or O(1) recurrent state for
SSM/RG-LRU archs — that is exactly what makes long_500k runnable for them).

:class:`BatchedServer` runs the vLLM-style loop on top (DESIGN.md §9):
a FIFO request queue with admission control, a :class:`~repro.serve.
scheduler.Scheduler` driving per-slot positions through chunked prefill
interleaved with decode ticks, slot recycling that resets the recycled
rows (per-slot ``pos`` tables make a recycled slot's old cache unreachable
— the shared-global-``pos`` server this replaces read the previous
occupant's cache), and an optional paged KV cache (``serve.paged_cache``)
whose blocks are quantized on write at an ``ActivationLayout`` wire dtype.

One jitted tick function per width, compiled once and reused (the old
server re-wrapped ``jax.jit`` every tick and re-traced each call); the
cache is donated through it.
"""
from __future__ import annotations

from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import cache_specs, param_specs
from repro.models.model import Model

from .paged_cache import (
    BlockAllocator,
    cache_bytes,
    cache_layout,
    paged_bits_per_token,
    release_blocks,
    reset_slots,
    select_slots,
)
from .scheduler import PREFILL, Request, Scheduler

__all__ = ["BatchedServer", "BuiltServe", "Request", "build_serve"]


class BuiltServe(NamedTuple):
    prefill: Callable            # (params, batch) -> (logits, cache)
    decode_step: Callable        # pure: (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Callable
    param_shardings: Any
    cache_sharding_fn: Callable
    init_paged_cache: Optional[Callable] = None
    mesh: Any = None
    dp: Any = None


def build_serve(model: Model, mesh, fsdp: Optional[str], tp: Optional[str],
                dp: Optional[str] = "data") -> BuiltServe:
    cfg = model.config
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, mesh, fsdp, tp)
    to_sh = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    param_shardings = to_sh(pspecs)

    def cache_sharding_fn(cache):
        return to_sh(cache_specs(cache, mesh, dp, tp))

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return BuiltServe(
        prefill=model.prefill,
        decode_step=decode_step,
        init_cache=model.init_cache,
        param_shardings=param_shardings,
        cache_sharding_fn=cache_sharding_fn,
        init_paged_cache=model.init_paged_cache,
        mesh=mesh,
        dp=dp,
    )


def _allowed_widths(cfg: ModelConfig, prefill_chunk: int) -> Tuple[int, ...]:
    """Tick widths the arch can execute: prefill_chunk halved down to 1.
    SSD archs additionally require every multi-token width to be a multiple
    of the SSD scan chunk (``ssd_chunked`` asserts seq % chunk == 0)."""
    ws = set()
    w = max(1, int(prefill_chunk))
    while w >= 1:
        ws.add(w)
        w //= 2
    if "ssd" in cfg.attn_pattern:
        c = cfg.ssm.chunk_size
        ws = {w for w in ws if w == 1 or w % c == 0}
    return tuple(sorted(ws, reverse=True))


class BatchedServer:
    """Continuous-batching server over a fixed decode batch size.

    Greedy sampling (argmax) — the engine is about the systems path, not
    sampling strategy. ``paged=None`` auto-enables the paged KV cache when
    the model has global-attention layers to page (``cache_dtype`` then
    selects the block wire dtype; ``None`` = compute dtype, bit-exact)."""

    def __init__(self, serve: BuiltServe, params, cfg: ModelConfig,
                 batch_size: int, max_seq: int, *,
                 paged: Optional[bool] = None, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 cache_dtype: Optional[str] = None,
                 prefill_chunk: int = 8, max_queue: Optional[int] = None):
        self.serve = serve
        self.params = params
        self.cfg = cfg
        self.batch = batch_size
        self.max_seq = max_seq
        self.max_queue = max_queue
        if paged is None:
            paged = serve.init_paged_cache is not None
        if paged and serve.init_paged_cache is None:
            raise ValueError(
                f"{cfg.name}: no global-attention layers to page"
            )
        self.paged = paged
        self.layout = cache_layout(cfg, cache_dtype if paged else None)

        if paged:
            if max_seq % block_size != 0:
                raise ValueError(f"max_seq {max_seq} % block_size {block_size}")
            self._nb_seq = max_seq // block_size
            if num_blocks is None:
                num_blocks = batch_size * self._nb_seq  # dense-equivalent pool
            self.allocator: Optional[BlockAllocator] = BlockAllocator(
                num_blocks, block_size
            )
            self.cache = serve.init_paged_cache(
                batch_size, max_seq, num_blocks, block_size,
                cache_dtype=self.layout.wire_dtype,
            )
            self._bt = np.full((batch_size, self._nb_seq), -1, np.int32)
            self.cache["bt"] = jnp.asarray(self._bt)
        else:
            self.allocator = None
            self.cache = serve.init_cache(batch_size, max_seq)

        self.scheduler = Scheduler(
            batch_size, max_seq,
            widths=_allowed_widths(cfg, prefill_chunk),
            allocator=self.allocator,
        )
        self.completed: List[dict] = []
        self.stats = {
            "ticks": 0, "prefill_tokens": 0, "decode_tokens": 0,
            "cache_bytes": cache_bytes(self.cache),
        }

        # one compiled tick per width; cache donated through each
        self._cache_shardings = serve.cache_sharding_fn(self.cache)
        mesh, dp = serve.mesh, serve.dp
        dsize = 1
        if mesh is not None and dp is not None:
            dsize = np.prod([mesh.shape[a] for a in (
                dp if isinstance(dp, (tuple, list)) else (dp,))])
        tok_spec = P(dp, None) if dsize > 1 and batch_size % dsize == 0 else P()
        self._tok_sharding = (
            NamedSharding(mesh, tok_spec) if mesh is not None else None
        )
        self._pos_sharding = (
            NamedSharding(mesh, P()) if mesh is not None else None
        )
        self._ticks: dict[int, Callable] = {}

        def _tick(params, cache, tokens, pos):
            logits, nc = serve.decode_step(params, cache, tokens, pos)
            return logits, select_slots(nc, cache, pos >= 0)

        self._tick_impl = _tick

    def _tick_fn(self, width: int) -> Callable:
        fn = self._ticks.get(width)
        if fn is None:
            mesh = self.serve.mesh
            logits_sharding = None
            if mesh is not None:
                logits_sharding = NamedSharding(
                    mesh, P(*(tuple(self._tok_sharding.spec) + (None,)))
                )
            fn = jax.jit(
                self._tick_impl,
                in_shardings=(
                    self.serve.param_shardings, self._cache_shardings,
                    self._tok_sharding, self._pos_sharding,
                ),
                # pin outputs so tick N+1's committed cache matches
                # in_shardings (GSPMD would otherwise pick its own layout)
                out_shardings=(logits_sharding, self._cache_shardings),
                donate_argnums=(1,),
            )
            self._ticks[width] = fn
        return fn

    # -- request lifecycle ---------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request. Raises ValueError when it can never fit
        (prompt + max_new - 1 > max_seq); returns False when the queue is
        at ``max_queue`` (backpressure), True otherwise."""
        self.scheduler.validate(req)
        if self.max_queue is not None and len(self.scheduler.queue) >= self.max_queue:
            return False
        self.scheduler.submit(req)
        return True

    def _admit(self) -> None:
        admitted = self.scheduler.admit()
        if not admitted:
            return
        # recycle the slots: per-slot pos rows -> -1, recurrent rows -> 0,
        # so the new occupant can never read the previous one's cache
        mask = np.zeros((self.batch,), bool)
        mask[admitted] = True
        self.cache = reset_slots(self.cache, jnp.asarray(mask))
        if self.paged:
            for i in admitted:
                self._bt[i] = -1
                blocks = self.scheduler.slots[i].blocks
                self._bt[i, : len(blocks)] = blocks
            self.cache["bt"] = jnp.asarray(self._bt)

    def tick(self) -> bool:
        """One engine step: admit, plan, run, commit. False when idle."""
        self._admit()
        plan = self.scheduler.plan()
        if plan is None:
            return False
        prompt_fed = sum(
            plan.width for i in plan.active
            if self.scheduler.slots[i].state == PREFILL
        )
        logits, self.cache = self._tick_fn(plan.width)(
            self.params, self.cache,
            jnp.asarray(plan.tokens), jnp.asarray(plan.pos),
        )
        sampled = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        completions, freed = self.scheduler.apply(plan, sampled)
        self.completed.extend(completions)
        if freed:
            # poison the freed blocks' position rows; bt rows are rewritten
            # at the slot's next admission
            self.allocator.free(freed)
            self.cache = release_blocks(
                self.cache, jnp.asarray(np.asarray(freed, np.int32))
            )
        self.stats["ticks"] += 1
        self.stats["prefill_tokens"] += prompt_fed
        self.stats["decode_tokens"] += len(plan.samplers)
        return True

    def drain(
        self, max_ticks: int = 10000, strict: bool = False
    ) -> Tuple[List[dict], List[int]]:
        """Run until idle or ``max_ticks``. Returns ``(completed, pending)``
        where ``pending`` is the uids still in flight or queued — never a
        silent truncation. ``strict=True`` raises instead when the tick
        budget expires with work outstanding."""
        t = 0
        while self.scheduler.n_pending > 0 and t < max_ticks:
            if not self.tick():
                break
            t += 1
        pending = self.scheduler.pending_uids()
        if strict and pending:
            raise RuntimeError(
                f"drain: {len(pending)} requests unfinished after "
                f"{max_ticks} ticks (uids {pending})"
            )
        return self.completed, pending

    # -- accounting ----------------------------------------------------

    def cache_stats(self) -> dict:
        """Cache memory + wire accounting for BENCH_serve.json."""
        out = dict(self.stats)
        out["paged"] = self.paged
        out["cache_dtype"] = self.layout.wire_dtype
        if self.paged:
            bits_tok = paged_bits_per_token(self.cfg, self.layout)
            al = self.allocator
            out["kv_bits_per_token"] = bits_tok
            out["block_high_water"] = al.high_water
            out["num_blocks"] = al.num_blocks
            # bytes actually pinned at peak vs the dense-equivalent cache
            out["high_water_bytes"] = al.high_water * al.block_size * bits_tok / 8
            out["dense_equiv_bytes"] = self.batch * self.max_seq * bits_tok / 8
        return out

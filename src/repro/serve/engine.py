"""Serving engine: prefill + batched decode steps.

Serving uses no SASG (inference has no gradient traffic); params are FSDP x
TP sharded like training so multi-hundred-GB models fit. `decode_step` is the
unit the decode_32k / long_500k dry-run shapes lower: one new token per
sequence against a seq_len KV cache (or O(1) recurrent state for SSM/RG-LRU
archs — that is exactly what makes long_500k runnable for them).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import cache_specs, param_specs
from repro.models.model import Model


class BuiltServe(NamedTuple):
    prefill: Callable            # (params, batch) -> (logits, cache)
    decode_step: Callable        # pure: (params, cache, tokens, pos) -> (logits, cache)
    jit_decode: Callable
    init_cache: Callable
    param_shardings: Any
    cache_sharding_fn: Callable


def build_serve(model: Model, mesh, fsdp: Optional[str], tp: Optional[str],
                dp: Optional[str] = "data") -> BuiltServe:
    cfg = model.config
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, mesh, fsdp, tp)
    to_sh = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    param_shardings = to_sh(pspecs)

    def cache_sharding_fn(cache):
        return to_sh(cache_specs(cache, mesh, dp, tp))

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    def jit_decode(params, cache, tokens, pos):
        fn = jax.jit(
            decode_step,
            in_shardings=(
                param_shardings,
                cache_sharding_fn(cache),
                NamedSharding(mesh, P(dp, None)),
                NamedSharding(mesh, P()),
            ),
            donate_argnums=(1,),
        )
        return fn(params, cache, tokens, pos)

    return BuiltServe(
        prefill=model.prefill,
        decode_step=decode_step,
        jit_decode=jit_decode,
        init_cache=model.init_cache,
        param_shardings=param_shardings,
        cache_sharding_fn=cache_sharding_fn,
    )


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16


class BatchedServer:
    """Minimal continuous-batching loop over a fixed decode batch size.

    Requests join free slots; every engine tick decodes one token for every
    active slot. Greedy sampling (argmax) — the engine is about the systems
    path, not sampling strategy."""

    def __init__(self, serve: BuiltServe, params, cfg: ModelConfig,
                 batch_size: int, max_seq: int):
        self.serve = serve
        self.params = params
        self.cfg = cfg
        self.batch = batch_size
        self.max_seq = max_seq
        self.cache = serve.init_cache(batch_size, max_seq)
        self.pos = jnp.zeros((), jnp.int32)
        self.slots: list[Optional[dict]] = [None] * batch_size
        self.completed: list[dict] = []

    def submit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = {
                    "req": req, "generated": [], "fed": 0,
                }
                return True
        return False

    def _next_tokens(self) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            req = s["req"]
            if s["fed"] < len(req.prompt):
                toks[i, 0] = req.prompt[s["fed"]]
                s["fed"] += 1
            elif s["generated"]:
                toks[i, 0] = s["generated"][-1]
        return toks

    def tick(self):
        toks = jnp.asarray(self._next_tokens())
        logits, self.cache = self.serve.jit_decode(
            self.params, self.cache, toks, self.pos
        )
        self.pos = self.pos + 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            req = s["req"]
            if s["fed"] >= len(req.prompt):
                s["generated"].append(int(nxt[i]))
                if len(s["generated"]) >= req.max_new_tokens:
                    self.completed.append(
                        {"uid": req.uid, "tokens": list(s["generated"])}
                    )
                    self.slots[i] = None

    def drain(self, max_ticks: int = 10000):
        t = 0
        while any(s is not None for s in self.slots) and t < max_ticks:
            self.tick()
            t += 1
        return self.completed

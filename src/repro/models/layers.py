"""Model-zoo building blocks, pure JAX (no flax): params are nested dicts,
every module is an (init, apply) pair of pure functions.

Conventions
-----------
- weights are stored 2D/3D with named roles so `dist/sharding.py` can assign
  PartitionSpecs from the param path (wq/wk/wv/wo, w_gate/w_up/w_down,
  experts_*, embed, lm_head, ...).
- compute dtype = cfg.compute_dtype (bf16 in production); softmax/logits and
  normalization statistics in fp32.
- attention is chunk-streamed (flash semantics: running max / normalizer via
  lax.scan over KV or Q blocks) so the S x S score matrix never materializes;
  sliding-window attention streams over a banded window only (O(S*W)).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Any
NEG_INF = -1e30


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _init_normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple:
    """cos/sin tables for `dim` rotary dims at integer positions (..., S)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, style: str) -> jax.Array:
    """x: (B, S, H, Dh). style: 'full' rotates all dims; 'half' (ChatGLM 2d
    RoPE) rotates only the first half of head dims and passes the rest.

    Rotate-half formulation (GPT-NeoX pairing: dims (i, i+rot/2)): contiguous
    split + concat only. The interleaved (2i, 2i+1) pairing needs strided
    slices + an interleaving reshape, which trips an XLA SPMD partitioner
    CHECK inside partial-manual shard_map regions (see
    tests/test_known_limits.py); the two pairings are equivalent up to a
    fixed permutation of frequencies."""
    dh = x.shape[-1]
    rot = dh if style == "full" else dh // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    c = cos[..., None, :]  # (..., S, 1, rot/2)
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < dh else out


# ---------------------------------------------------------------------------
# attention (GQA) — chunk-streamed softmax
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    return {
        "wq": _init_normal(ks[0], (d, hq * dh), sc, _pdtype(cfg)),
        "wk": _init_normal(ks[1], (d, hkv * dh), sc, _pdtype(cfg)),
        "wv": _init_normal(ks[2], (d, hkv * dh), sc, _pdtype(cfg)),
        "wo": _init_normal(ks[3], (hq * dh, d), 1.0 / math.sqrt(hq * dh), _pdtype(cfg)),
    }


def _gqa_expand(q: jax.Array, hkv: int) -> jax.Array:
    """(B,S,Hq,Dh) -> (B,S,Hkv,G,Dh) grouping query heads onto kv heads."""
    b, s, hq, dh = q.shape
    return q.reshape(b, s, hkv, hq // hkv, dh)


def _chunked_softmax_attend(
    q: jax.Array,        # (B, Sq, Hkv, G, Dh) fp32-scaled
    k: jax.Array,        # (B, Skv, Hkv, Dh)
    v: jax.Array,        # (B, Skv, Hkv, Dh)
    q_offset,            # scalar: absolute position of q[0]
    causal: bool,
    window: int,         # 0 = unbounded
    kv_chunk: int,
) -> jax.Array:
    """Flash-semantics streaming attention over KV chunks via lax.scan.

    Never materializes (Sq, Skv); peak extra memory is (B, Sq, H, kv_chunk).
    """
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    kv_chunk = min(kv_chunk, skv)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        ci, kblk, vblk = inp
        kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q, kblk.astype(q.dtype),
            preferred_element_type=jnp.float32,
        )
        mask = (kv_pos[None, :] <= q_pos[:, None]) if causal else jnp.ones(
            (sq, kv_chunk), bool
        )
        mask = mask & (kv_pos[None, :] < skv)
        if window:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), ()

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4)  # (B, Sq, Hkv, G, Dh)


def _attend_masked(
    qg: jax.Array,       # (B, Sq, Hkv, G, Dh) scaled queries
    k: jax.Array,        # (B, Skv, Hkv, Dh)
    v: jax.Array,        # (B, Skv, Hkv, Dh)
    q_pos: jax.Array,    # (B, Sq) absolute query positions
    kv_pos: jax.Array,   # (B, Skv) absolute key positions, -1 = empty slot
    window: int,         # 0 = unbounded
) -> jax.Array:
    """Single-block flash-form attention with explicit position masks.

    The one-chunk specialization of `_chunked_softmax_attend`: same m/l/acc
    max-subtraction algebra, so a decode chain over a cache reproduces the
    full-sequence forward *bitwise* for attention archs (the serve parity
    contract, tests/test_serve_engine.py). Fully-masked rows (frozen slots,
    q_pos < 0) come out finite, never NaN."""
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    mask = (kv_pos[:, None, :] <= q_pos[:, :, None]) & (kv_pos[:, None, :] >= 0)
    if window:
        mask = mask & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum(
        "bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4)  # (B, Sq, Hkv, G, Dh)


def attention_apply(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                      # (B, S, d)
    positions: jax.Array,              # (S,) or (B, S) absolute positions
    kind: str = "global",              # "global" | "swa" | "local"
    cache: Optional[dict] = None,      # decode: see below
    cross_kv: Optional[tuple] = None,  # encdec cross-attn: (k, v) precomputed
    causal: bool = True,
    kv_chunk: int = 1024,
    block_table: Optional[jax.Array] = None,  # paged cache: (B, nb) block ids
):
    """Attention with an optional decode cache.

    Cache forms (DESIGN.md §9):
      - dense: {"k","v"} (B, L, Hkv, Dh) + "pos" (B, L) absolute positions
        (-1 = empty). Writes scatter each token at its absolute position
        (mod L for the windowed ring buffers).
      - paged: {"pk","pv"} (NB, block, Hkv, Dh) + "ppos" (NB, block), read
        and written through ``block_table`` (B, nb; -1 = unassigned block).
    ``positions`` may be per-batch (B, S); rows with negative positions are
    frozen slots — their cache writes are dropped (OOB scatter indices) and
    their outputs are garbage-but-finite, to be discarded by the caller.
    """
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = _dtype(cfg)
    x = x.astype(dt)

    q = (x @ params["wq"].astype(dt)).reshape(b, s, hq, dh)
    if cross_kv is None:
        k = (x @ params["wk"].astype(dt)).reshape(b, s, hkv, dh)
        v = (x @ params["wv"].astype(dt)).reshape(b, s, hkv, dh)
        cos, sin = rope_angles(
            positions, dh if cfg.rope_style == "full" else dh // 2, cfg.rope_theta
        )
        q = apply_rope(q, cos, sin, cfg.rope_style)
        k = apply_rope(k, cos, sin, cfg.rope_style)
    else:
        k, v = cross_kv

    new_cache = None
    incremental = False
    kv_pos = None
    if cache is not None and cross_kv is None:
        paged = "pk" in cache
        pos2d = (
            positions if positions.ndim == 2
            else jnp.broadcast_to(positions[None], (b, s))
        ).astype(jnp.int32)
        if paged:
            # paged cache: scatter fresh K/V into the block pool through the
            # block table, then gather the per-sequence view back. Rows with
            # pos < 0 (frozen slots) and -1 table entries map to an OOB block
            # id and are dropped; negative ids would WRAP in jax indexing.
            incremental = True
            nb_pool, bs_blk = cache["ppos"].shape
            nb_seq = block_table.shape[1]
            blk_idx = jnp.clip(jnp.where(pos2d >= 0, pos2d // bs_blk, 0),
                               0, nb_seq - 1)
            blk = jnp.take_along_axis(block_table, blk_idx, axis=1)  # (B, S)
            blk = jnp.where((pos2d >= 0) & (blk >= 0), blk, nb_pool)
            off = jnp.where(pos2d >= 0, pos2d % bs_blk, 0)
            pk = cache["pk"].at[blk, off].set(
                k.astype(cache["pk"].dtype), mode="drop")
            pv = cache["pv"].at[blk, off].set(
                v.astype(cache["pv"].dtype), mode="drop")
            pp = cache["ppos"].at[blk, off].set(pos2d, mode="drop")
            new_cache = {"pk": pk, "pv": pv, "ppos": pp}
            # gather: mode="fill" treats -1 table entries as OOB (no wrap),
            # so unassigned blocks read as zeros with pos = -1 (masked out)
            k = jnp.take(pk, block_table, axis=0, mode="fill",
                         fill_value=0).reshape(b, nb_seq * bs_blk, hkv, dh)
            v = jnp.take(pv, block_table, axis=0, mode="fill",
                         fill_value=0).reshape(b, nb_seq * bs_blk, hkv, dh)
            kv_pos = jnp.take(pp, block_table, axis=0, mode="fill",
                              fill_value=-1).reshape(b, nb_seq * bs_blk)
        else:
            cache_len = cache["k"].shape[1]
            if s > 1 and s >= cache_len:
                # prefill into a bounded (ring) cache: keep only the last
                # cache_len keys/values; attention below runs on the full seq
                ck = k[:, s - cache_len:].astype(cache["k"].dtype)
                cv = v[:, s - cache_len:].astype(cache["v"].dtype)
                cp = pos2d[:, s - cache_len:]
                new_cache = {"k": ck, "v": cv, "pos": cp}
            else:
                # incremental write (decode tick or chunked-prefill
                # continuation): scatter each token at its absolute position
                # — windowed caches are ring buffers, slot != time
                incremental = True
                slot = jnp.where(pos2d >= 0, pos2d % cache_len, cache_len)
                bidx = jnp.arange(b)[:, None]
                ck = cache["k"].at[bidx, slot].set(
                    k.astype(cache["k"].dtype), mode="drop")
                cv = cache["v"].at[bidx, slot].set(
                    v.astype(cache["v"].dtype), mode="drop")
                cp = cache["pos"].at[bidx, slot].set(pos2d, mode="drop")
                new_cache = {"k": ck, "v": cv, "pos": cp}
                k, v, kv_pos = ck, cv, cp

    qg = _gqa_expand(q, hkv) * (1.0 / math.sqrt(dh))
    window = cfg.window if kind in ("swa", "local") else 0

    if incremental:
        # decode / continuation path: attend over the updated cache with
        # explicit position masks (per-slot positions under the serve engine)
        out = _attend_masked(qg, k, v, pos2d, kv_pos, window)
    else:
        if cross_kv is not None:
            q_off = 0
        else:
            q_off = positions[0] if positions.ndim == 1 else positions[0, 0]
        out = _chunked_softmax_attend(
            qg.astype(jnp.float32), k, v, q_off,
            causal=causal and cross_kv is None, window=window, kv_chunk=kv_chunk,
        )

    out = out.reshape(b, s, hq * dh).astype(dt)
    return out @ params["wo"].astype(dt), new_cache


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "w_gate": _init_normal(ks[0], (d, ff), sc_in, _pdtype(cfg)),
            "w_up": _init_normal(ks[1], (d, ff), sc_in, _pdtype(cfg)),
            "w_down": _init_normal(ks[2], (ff, d), sc_out, _pdtype(cfg)),
        }
    return {
        "w_up": _init_normal(ks[0], (d, ff), sc_in, _pdtype(cfg)),
        "w_down": _init_normal(ks[1], (ff, d), sc_out, _pdtype(cfg)),
    }


def mlp_apply(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = _dtype(cfg)
    x = x.astype(dt)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_variant == "swiglu" else partial(
            jax.nn.gelu, approximate=True
        )
        h = act(x @ params["w_gate"].astype(dt)) * (x @ params["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(dt), approximate=True)
    return h @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Mixture of Experts — GShard-style dense dispatch with per-group capacity
# ---------------------------------------------------------------------------

def moe_group_size(cfg: ModelConfig) -> int:
    # keep the dispatch one-hot ~ T_local * group * k * cf bounded
    return 256 if cfg.moe.top_k >= 4 else 1024


def moe_init(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_expert
    ks = jax.random.split(key, 5)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": _init_normal(ks[0], (d, e), sc_in, jnp.float32),
        "experts_gate": _init_normal(ks[1], (e, d, f), sc_in, _pdtype(cfg)),
        "experts_up": _init_normal(ks[2], (e, d, f), sc_in, _pdtype(cfg)),
        "experts_down": _init_normal(ks[3], (e, f, d), sc_out, _pdtype(cfg)),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=m.d_expert * m.num_shared_experts)
    return p


def moe_apply(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d). Dense (GShard) dispatch: tokens grouped into blocks of
    `group` with per-group expert capacity C = group*k/E*cf; one-hot dispatch
    and combine einsums keep everything MXU-friendly and shardable (group dim
    follows the batch/data sharding, expert dim follows the model axis)."""
    m = cfg.moe
    dt = _dtype(cfg)
    b, s, d = x.shape
    group = min(moe_group_size(cfg), b * s)
    t = b * s
    assert t % group == 0, f"tokens {t} not divisible by moe group {group}"
    g = t // group
    e, k = m.num_experts, m.top_k
    cap = max(1, int(math.ceil(group * k / e * m.capacity_factor)))

    xt = x.reshape(g, group, d)
    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (g, t, e)
    topw, tope = jax.lax.top_k(probs, k)                        # (g, t, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's per-group queue
    sel = jax.nn.one_hot(tope, e, dtype=jnp.int32)              # (g, t, k, e)
    # rank over flattened (t, k) per group, preserving priority order
    flat_sel = sel.reshape(g, group * k, e)
    pos = jnp.cumsum(flat_sel, axis=1) - flat_sel               # (g, t*k, e)
    slot = jnp.sum(pos * flat_sel, axis=-1).reshape(g, group, k)
    keep = slot < cap
    slot = jnp.minimum(slot, cap - 1)

    # dispatch/combine one-hots (g, t, k, e, cap) collapsed over k
    slot_oh = jax.nn.one_hot(slot, cap, dtype=dt)               # (g, t, k, cap)
    disp = jnp.einsum(
        "gtke,gtkc->gtec", sel.astype(dt) * keep[..., None].astype(dt), slot_oh
    )                                                            # (g, t, e, cap)
    comb = jnp.einsum(
        "gtke,gtkc,gtk->gtec",
        sel.astype(dt), slot_oh, (topw * keep).astype(dt),
    )

    buf = jnp.einsum("gtd,gtec->gecd", xt.astype(dt), disp)     # (g, e, cap, d)
    h = jnp.einsum("gecd,edf->gecf", buf, params["experts_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buf, params["experts_up"].astype(dt))
    h = jax.nn.silu(h) * u
    out_e = jnp.einsum("gecf,efd->gecd", h, params["experts_down"].astype(dt))
    y = jnp.einsum("gecd,gtec->gtd", out_e, comb)

    y = y.reshape(b, s, d)
    if m.num_shared_experts:
        y = y + mlp_apply(params["shared"], cfg, x)
    return y


def moe_aux_loss(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (used in training)."""
    m = cfg.moe
    logits = (x.reshape(-1, x.shape[-1]).astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, -1)
    top1 = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32), 0)
    imp = jnp.mean(probs, 0)
    return m.num_experts * jnp.sum(frac * imp)


# ---------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig) -> Params:
    return {
        "embed": _init_normal(
            key, (cfg.vocab_size, cfg.d_model), 1.0, _pdtype(cfg)
        )
    }


def embed_apply(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return params["embed"].astype(_dtype(cfg))[tokens]


def lm_head_init(key, cfg: ModelConfig) -> Params:
    return {
        "lm_head": _init_normal(
            key, (cfg.d_model, cfg.vocab_size), 1.0 / math.sqrt(cfg.d_model),
            _pdtype(cfg),
        )
    }


def lm_head_apply(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return x.astype(_dtype(cfg)) @ params["lm_head"].astype(_dtype(cfg))

"""Unified model API: build(config) -> Model with init / loss / prefill /
decode, covering every assigned architecture family plus the paper's own
models. Shapes in batches are GLOBAL (auto-SPMD view).

Batch formats:
  LM families:  {"tokens": (B,S) int32, "labels": (B,S) int32}
  vlm:          + "patch_embeds": (B, Np, d)   (stub frontend, Np prefix)
  audio encdec: {"frames": (B,S_src,d), "tokens": (B,S_tgt), "labels": ...}
  mlp/cnn:      {"x": images, "labels": (B,) int32}
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import encdec as ED
from . import layers as L
from . import lm as LM
from . import paper_nets as PN

NUM_PATCH_TOKENS = 256     # VLM stub prefix length
ENC_FRAC = 2               # enc-dec: S_src = S_tgt = seq_len // 2


class Model(NamedTuple):
    config: ModelConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[[Any, Any], jax.Array]           # (params, batch) -> loss
    prefill: Optional[Callable]                        # (params, batch) -> (logits, cache)
    decode_step: Optional[Callable]                    # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Optional[Callable]                     # (batch, max_seq) -> cache


def chunked_ce(
    hidden: jax.Array,       # (B, S, d)
    head_w: jax.Array,       # (d, V)
    labels: jax.Array,       # (B, S)
    n_chunks: int = 8,
) -> jax.Array:
    """Cross-entropy with the (B,S,V) logits materialized one S-chunk at a
    time (fp32 logits over a 128k-256k vocab dominate activation memory
    otherwise)."""
    b, s, d = hidden.shape
    n_chunks = min(n_chunks, s)
    while s % n_chunks:
        n_chunks -= 1
    hs = hidden.reshape(b, n_chunks, s // n_chunks, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, s // n_chunks).transpose(1, 0, 2)

    def body(acc, inp):
        h, lab = inp
        logits = (h @ head_w.astype(h.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), ()

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


def _head_weight(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# decoder-only LM families (dense / moe / hybrid / ssm / vlm)
# ---------------------------------------------------------------------------

def _build_lm(cfg: ModelConfig, remat: str) -> Model:
    is_vlm = cfg.frontend == "patch_embed"

    def init(key):
        return LM.lm_init(key, cfg)

    def loss_fn(params, batch):
        prefix = batch.get("patch_embeds") if is_vlm else None
        hidden, _ = LM.lm_forward(
            params, cfg, batch["tokens"], prefix_embeds=prefix, remat=remat,
            return_hidden=True,
        )
        if is_vlm and prefix is not None:
            hidden = hidden[:, prefix.shape[1]:]
        return chunked_ce(hidden, _head_weight(params, cfg), batch["labels"])

    def prefill(params, batch):
        prefix = batch.get("patch_embeds") if is_vlm else None
        b = batch["tokens"].shape[0]
        s = batch["tokens"].shape[1] + (prefix.shape[1] if prefix is not None else 0)
        cache = LM.lm_init_cache(cfg, b, s)
        logits, cache = LM.lm_forward(
            params, cfg, batch["tokens"], prefix_embeds=prefix,
            cache=cache, cache_pos=jnp.zeros((), jnp.int32), remat=remat,
        )
        return logits, cache

    def decode_step(params, cache, tokens, pos):
        logits, cache = LM.lm_forward(
            params, cfg, tokens, cache=cache, cache_pos=pos
        )
        return logits, cache

    def init_cache(batch, max_seq):
        return LM.lm_init_cache(cfg, batch, max_seq)

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# encoder-decoder (audio)
# ---------------------------------------------------------------------------

def _build_encdec(cfg: ModelConfig, remat: str) -> Model:
    def init(key):
        return ED.encdec_init(key, cfg)

    def loss_fn(params, batch):
        enc = ED.encode(params, cfg, batch["frames"], remat=remat)
        xkv = ED.cross_kv(params, cfg, enc)
        logits, _ = ED.decode(params, cfg, batch["tokens"], xkv, remat=remat)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    def prefill(params, batch):
        enc = ED.encode(params, cfg, batch["frames"], remat=remat)
        xkv = ED.cross_kv(params, cfg, enc)
        b, s = batch["tokens"].shape
        cache = ED.encdec_init_cache(cfg, b, s)
        logits, cache = ED.decode(
            params, cfg, batch["tokens"], xkv, cache=cache,
            cache_pos=jnp.zeros((), jnp.int32), remat=remat,
        )
        return logits, {"self": cache, "xkv": xkv}

    def decode_step(params, cache, tokens, pos):
        logits, self_cache = ED.decode(
            params, cfg, tokens, cache["xkv"], cache=cache["self"], cache_pos=pos
        )
        return logits, {"self": self_cache, "xkv": cache["xkv"]}

    def init_cache(batch, max_seq):
        # cross-attn KV sized for a fixed source window at decode time
        src = min(max_seq, 4096)
        dt = jnp.dtype(cfg.compute_dtype)
        xkv = {
            "k": jnp.zeros((cfg.n_layers, batch, src, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((cfg.n_layers, batch, src, cfg.n_kv_heads, cfg.head_dim), dt),
        }
        return {"self": ED.encdec_init_cache(cfg, batch, max_seq), "xkv": xkv}

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# paper models
# ---------------------------------------------------------------------------

def _build_paper(cfg: ModelConfig) -> Model:
    is_fc = cfg.family == "mlp"

    def init(key):
        return PN.fc_init(key, cfg) if is_fc else PN.cnn_init(key, cfg)

    def loss_fn(params, batch):
        logits = (PN.fc_apply if is_fc else PN.cnn_apply)(params, cfg, batch["x"])
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None], axis=-1
        )[..., 0]
        return jnp.mean(lse - gold)

    def predict(params, batch):
        return (PN.fc_apply if is_fc else PN.cnn_apply)(params, cfg, batch["x"])

    return Model(cfg, init, loss_fn, predict, None, None)


def build(cfg: ModelConfig, remat: str = "none") -> Model:
    if cfg.family in ("mlp", "cnn"):
        return _build_paper(cfg)
    if cfg.is_encdec:
        return _build_encdec(cfg, remat)
    return _build_lm(cfg, remat)

"""Unified model API: build(config) -> Model with init / loss / prefill /
decode, covering every assigned architecture family plus the paper's own
models. Shapes in batches are GLOBAL (auto-SPMD view).

Batch formats:
  LM families:  {"tokens": (B,S) int32, "labels": (B,S) int32}
  vlm:          + "patch_embeds": (B, Np, d)   (stub frontend, Np prefix)
  audio encdec: {"frames": (B,S_src,d), "tokens": (B,S_tgt), "labels": ...}
  mlp/cnn:      {"x": images, "labels": (B,) int32}
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import encdec as ED
from . import layers as L
from . import lm as LM
from . import paper_nets as PN

NUM_PATCH_TOKENS = 256     # VLM stub prefix length
ENC_FRAC = 2               # enc-dec: S_src = S_tgt = seq_len // 2


class PipelineDef(NamedTuple):
    """Stage-decomposed view of a model for GPipe pipelining (dist.pipeline).

    The model's homogeneous *trunk* — ``n_layers`` identical-structure layers
    whose params live stacked on a leading layer dim under ``trunk_path`` in
    the params tree, and whose activations keep one shape end to end — is the
    pipelineable segment. ``prepare``/``finish`` hold everything before/after
    it (embed/stem, remainder layers, norm, head, loss) and MUST NOT read the
    trunk subtree: inside the train step's shard_map the trunk leaves are the
    local stage slice, not the full stack.
    """

    n_layers: int                                      # trunk depth (stacked dim)
    trunk_path: tuple                                  # params-tree path of the trunk
    prepare: Callable[[Any, Any], jax.Array]           # (params, batch) -> h (B, ...)
    layer_fn: Callable[[Any, jax.Array], jax.Array]    # (layer_params, h) -> h
    finish: Callable[[Any, jax.Array, Any], jax.Array]  # (params, h, batch) -> loss
    # Params-tree path prefixes read ONLY by ``prepare`` (disjoint from the
    # leaves ``finish`` reads). When set, dist.pipeline can compute
    # stage-LOCAL gradients (the payload-level stage gather path): finish
    # grads replicate for free, prepare grads need one tiny psum over these
    # leaves, and trunk grads stay stage-sliced. ``None`` means the split is
    # not expressible (e.g. tied embeddings read by both sides) and the
    # dense stage-combine fallback must be used.
    prepare_paths: Optional[tuple] = None


class Model(NamedTuple):
    config: ModelConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[[Any, Any], jax.Array]           # (params, batch) -> loss
    prefill: Optional[Callable]                        # (params, batch) -> (logits, cache)
    decode_step: Optional[Callable]                    # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Optional[Callable]                     # (batch, max_seq) -> cache
    pipeline: Optional[PipelineDef] = None             # stage decomposition (or None)
    # paged decode cache (batch, max_seq, num_blocks, block_size,
    # cache_dtype) -> cache with a "bt" block table; None when the arch has
    # no global-attention layers to page (DESIGN.md §9)
    init_paged_cache: Optional[Callable] = None


def chunked_ce(
    hidden: jax.Array,       # (B, S, d)
    head_w: jax.Array,       # (d, V)
    labels: jax.Array,       # (B, S)
    n_chunks: int = 8,
) -> jax.Array:
    """Cross-entropy with the (B,S,V) logits materialized one S-chunk at a
    time (fp32 logits over a 128k-256k vocab dominate activation memory
    otherwise)."""
    b, s, d = hidden.shape
    n_chunks = min(n_chunks, s)
    while s % n_chunks:
        n_chunks -= 1
    hs = hidden.reshape(b, n_chunks, s // n_chunks, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, s // n_chunks).transpose(1, 0, 2)

    def body(acc, inp):
        h, lab = inp
        logits = (h @ head_w.astype(h.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), ()

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


def _head_weight(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# decoder-only LM families (dense / moe / hybrid / ssm / vlm)
# ---------------------------------------------------------------------------

def _lm_pipeline(cfg: ModelConfig, remat: str) -> Optional[PipelineDef]:
    """Stage decomposition of the unit-scanned LM stack.

    Only homogeneous patterns (one layer kind per unit, ``u == 1``) pipeline:
    the trunk is ``params["unit"][0]`` with all ``n_layers`` layers stacked on
    the leading dim (and ``rem == 0`` by construction), so activations keep
    the (B, S, d) shape across every stage boundary. ``remat`` applies per
    trunk layer, mirroring the per-unit policy of the scanned forward.
    """
    u, n_units, rem = LM._unit_layout(cfg)
    if u != 1 or rem != 0 or n_units < 1:
        return None
    kind = cfg.attn_pattern[0]
    is_vlm = cfg.frontend == "patch_embed"

    def prepare(params, batch):
        x = L.embed_apply(params, cfg, batch["tokens"])
        prefix = batch.get("patch_embeds") if is_vlm else None
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        return x

    def layer_fn(wl, h):
        positions = jnp.arange(h.shape[1])
        h, _ = LM._layer_apply(wl, cfg, kind, h, positions)
        return h

    if remat == "full":
        layer_fn = jax.checkpoint(layer_fn)
    elif remat == "dots":
        layer_fn = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )

    def finish(params, h, batch):
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        prefix = batch.get("patch_embeds") if is_vlm else None
        if prefix is not None:
            h = h[:, prefix.shape[1]:]
        return chunked_ce(h, _head_weight(params, cfg), batch["labels"])

    return PipelineDef(
        n_units, ("unit", 0), prepare, layer_fn, finish,
        # tied embeddings are read by prepare AND finish — no disjoint split
        prepare_paths=None if cfg.tie_embeddings else (("embed",),),
    )


def _build_lm(cfg: ModelConfig, remat: str) -> Model:
    is_vlm = cfg.frontend == "patch_embed"

    def init(key):
        return LM.lm_init(key, cfg)

    def loss_fn(params, batch):
        prefix = batch.get("patch_embeds") if is_vlm else None
        hidden, _ = LM.lm_forward(
            params, cfg, batch["tokens"], prefix_embeds=prefix, remat=remat,
            return_hidden=True,
        )
        if is_vlm and prefix is not None:
            hidden = hidden[:, prefix.shape[1]:]
        return chunked_ce(hidden, _head_weight(params, cfg), batch["labels"])

    def prefill(params, batch):
        prefix = batch.get("patch_embeds") if is_vlm else None
        b = batch["tokens"].shape[0]
        s = batch["tokens"].shape[1] + (prefix.shape[1] if prefix is not None else 0)
        cache = LM.lm_init_cache(cfg, b, s)
        logits, cache = LM.lm_forward(
            params, cfg, batch["tokens"], prefix_embeds=prefix,
            cache=cache, cache_pos=jnp.zeros((), jnp.int32), remat=remat,
        )
        return logits, cache

    def decode_step(params, cache, tokens, pos):
        logits, cache = LM.lm_forward(
            params, cfg, tokens, cache=cache, cache_pos=pos
        )
        return logits, cache

    def init_cache(batch, max_seq):
        return LM.lm_init_cache(cfg, batch, max_seq)

    def init_paged_cache(batch, max_seq, num_blocks, block_size,
                         cache_dtype=None):
        return LM.lm_init_paged_cache(
            cfg, batch, max_seq, num_blocks, block_size, cache_dtype
        )

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache,
                 pipeline=_lm_pipeline(cfg, remat),
                 init_paged_cache=(
                     init_paged_cache if "global" in cfg.attn_pattern else None
                 ))


# ---------------------------------------------------------------------------
# encoder-decoder (audio)
# ---------------------------------------------------------------------------

def _build_encdec(cfg: ModelConfig, remat: str) -> Model:
    def init(key):
        return ED.encdec_init(key, cfg)

    def loss_fn(params, batch):
        enc = ED.encode(params, cfg, batch["frames"], remat=remat)
        xkv = ED.cross_kv(params, cfg, enc)
        logits, _ = ED.decode(params, cfg, batch["tokens"], xkv, remat=remat)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    def prefill(params, batch):
        enc = ED.encode(params, cfg, batch["frames"], remat=remat)
        xkv = ED.cross_kv(params, cfg, enc)
        b, s = batch["tokens"].shape
        cache = ED.encdec_init_cache(cfg, b, s)
        logits, cache = ED.decode(
            params, cfg, batch["tokens"], xkv, cache=cache,
            cache_pos=jnp.zeros((), jnp.int32), remat=remat,
        )
        return logits, {"self": cache, "xkv": xkv}

    def decode_step(params, cache, tokens, pos):
        logits, self_cache = ED.decode(
            params, cfg, tokens, cache["xkv"], cache=cache["self"], cache_pos=pos
        )
        return logits, {"self": self_cache, "xkv": cache["xkv"]}

    def init_cache(batch, max_seq):
        # cross-attn KV sized for a fixed source window at decode time
        src = min(max_seq, 4096)
        dt = jnp.dtype(cfg.compute_dtype)
        xkv = {
            "k": jnp.zeros((cfg.n_layers, batch, src, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((cfg.n_layers, batch, src, cfg.n_kv_heads, cfg.head_dim), dt),
        }
        return {"self": ED.encdec_init_cache(cfg, batch, max_seq), "xkv": xkv}

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# paper models
# ---------------------------------------------------------------------------

def _softmax_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(lse - gold)


def _cnn_pipeline(cfg: ModelConfig) -> PipelineDef:
    """CNN stage decomposition: the full-width stride-1 trunk blocks pipeline
    (homogeneous activation shape); stem and the stride-2 downsampling stages
    run replicated in prepare/finish (their activation shapes change at block
    boundaries, so they cannot ride the homogeneous GPipe ring)."""

    def prepare(params, batch):
        return PN.cnn_stem(params, batch["x"])

    def finish(params, h, batch):
        return _softmax_ce(PN.cnn_head(params, h), batch["labels"])

    return PipelineDef(
        PN.CNN_TRUNK_DEPTH, ("trunk",), prepare,
        lambda wl, h: PN.cnn_trunk_block(wl, h), finish,
        prepare_paths=(("stem",), ("gn0",)),
    )


def _build_paper(cfg: ModelConfig) -> Model:
    is_fc = cfg.family == "mlp"

    def init(key):
        return PN.fc_init(key, cfg) if is_fc else PN.cnn_init(key, cfg)

    def loss_fn(params, batch):
        logits = (PN.fc_apply if is_fc else PN.cnn_apply)(params, cfg, batch["x"])
        return _softmax_ce(logits, batch["labels"])

    def predict(params, batch):
        return (PN.fc_apply if is_fc else PN.cnn_apply)(params, cfg, batch["x"])

    return Model(cfg, init, loss_fn, predict, None, None,
                 pipeline=None if is_fc else _cnn_pipeline(cfg))


def build(cfg: ModelConfig, remat: str = "none") -> Model:
    if cfg.family in ("mlp", "cnn"):
        return _build_paper(cfg)
    if cfg.is_encdec:
        return _build_encdec(cfg, remat)
    return _build_lm(cfg, remat)

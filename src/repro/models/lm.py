"""Decoder-only LM assembly: scan-over-layers with heterogeneous layer
patterns (dense / MoE / SWA / RG-LRU / SSD), KV caches for decode, and
modality-stub prefix embeddings (VLM).

Layers are grouped into repeating *units* (cfg.attn_pattern); the layer stack
is a ``lax.scan`` over units (keeps HLO size O(unit) instead of O(depth) —
essential for 61-layer compile times), with any remainder layers applied
unscanned so configs like RecurrentGemma's 38 = 12x(rglru,rglru,local)+2
lower with their exact depth.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L
from . import rglru as R
from . import ssd as S

Params = Any


# ---------------------------------------------------------------------------
# layer unit: init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": L.rmsnorm_init(cfg.d_model, jnp.float32)}
    if kind in ("global", "swa", "local"):
        p["attn"] = L.attention_init(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"] = R.rglru_block_init(ks[0], cfg)
    elif kind == "ssd":
        p["ssd"] = S.ssd_block_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "ssd":  # mamba2 blocks have no separate MLP
        p["norm2"] = L.rmsnorm_init(cfg.d_model, jnp.float32)
        if cfg.moe is not None:
            p["moe"] = L.moe_init(ks[1], cfg)
        else:
            p["mlp"] = L.mlp_init(ks[1], cfg)
    return p


def _layer_state_init(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    """Decode-time per-layer state."""
    if kind in ("global", "swa", "local"):
        # windowed attention uses a bounded ring buffer (this is what makes
        # long_500k decode O(window) for swa/local archs)
        cache_len = max_seq if kind == "global" else min(max_seq, cfg.window * 2)
        shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
        dt = jnp.dtype(cfg.compute_dtype)
        return {
            "k": jnp.zeros(shape, dt),
            "v": jnp.zeros(shape, dt),
            "pos": jnp.full((cache_len,), -1, jnp.int32),
        }
    if kind == "rglru":
        return R.rglru_init_state(cfg, batch)
    if kind == "ssd":
        return S.ssd_init_state(cfg, batch)
    raise ValueError(kind)


def _layer_apply(
    params: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    state=None,
    cache_pos=None,
):
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind in ("global", "swa", "local"):
        # windowed caches: write position is modulo the cache length
        cpos = cache_pos
        if state is not None and kind in ("swa", "local"):
            cpos = cache_pos % state["k"].shape[1]
        out, new_state = L.attention_apply(
            params["attn"], cfg, h, positions, kind=kind,
            cache=state, cache_pos=cpos,
        )
    elif kind == "rglru":
        out, new_state = R.rglru_block_apply(params["rglru"], cfg, h, state)
    elif kind == "ssd":
        out, new_state = S.ssd_block_apply(params["ssd"], cfg, h, state)
    else:
        raise ValueError(kind)
    x = x + out

    if kind != "ssd":
        h2 = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            x = x + L.moe_apply(params["moe"], cfg, h2)
        else:
            x = x + L.mlp_apply(params["mlp"], cfg, h2)
    return x, new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _unit_layout(cfg: ModelConfig):
    u = len(cfg.attn_pattern)
    n_units = cfg.n_layers // u
    rem = cfg.n_layers - n_units * u
    return u, n_units, rem


def lm_init(key, cfg: ModelConfig) -> Params:
    u, n_units, rem = _unit_layout(cfg)
    ks = jax.random.split(key, 3 + u * n_units + rem)
    params: dict = {}
    params.update(L.embed_init(ks[0], cfg))
    # stacked unit params: for each position j in the unit, leaves stacked
    # over n_units along a new leading axis
    unit = []
    ki = 3
    for j in range(u):
        per = [
            _layer_init(ks[ki + i * u + j], cfg, cfg.attn_pattern[j])
            for i in range(n_units)
        ]
        unit.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    params["unit"] = unit
    ki += u * n_units
    params["rem"] = [
        _layer_init(ks[ki + j], cfg, cfg.attn_pattern[j]) for j in range(rem)
    ]
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, jnp.float32)
    if not cfg.tie_embeddings:
        params.update(L.lm_head_init(ks[1], cfg))
    return params


def lm_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Any:
    u, n_units, rem = _unit_layout(cfg)
    unit = []
    for j in range(u):
        st = _layer_state_init(cfg, cfg.attn_pattern[j], batch, max_seq)
        unit.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (n_units,) + x.shape), st))
    remst = [
        _layer_state_init(cfg, cfg.attn_pattern[j], batch, max_seq)
        for j in range(rem)
    ]
    return {"unit": unit, "rem": remst}


def _stack_body(cfg: ModelConfig, positions, cache_pos, remat: str):
    u = len(cfg.attn_pattern)

    def unit_body(x, unit_params, unit_state):
        new_states = []
        for j in range(u):
            st = None if unit_state is None else unit_state[j]
            x, ns = _layer_apply(
                unit_params[j], cfg, cfg.attn_pattern[j], x, positions, st, cache_pos
            )
            new_states.append(ns)
        return x, new_states

    if remat == "full":
        unit_body = jax.checkpoint(unit_body)
    elif remat == "dots":
        unit_body = jax.checkpoint(
            unit_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    return unit_body


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                   # (B, S)
    prefix_embeds: Optional[jax.Array] = None,  # VLM stub: (B, Np, d)
    cache: Optional[Any] = None,
    cache_pos=None,                      # decode write position (scalar)
    remat: str = "none",
    return_hidden: bool = False,
):
    """Returns (logits-or-hidden, new_cache_or_None)."""
    x = L.embed_apply(params, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    seq = x.shape[1]
    pos0 = 0 if cache_pos is None else cache_pos
    positions = pos0 + jnp.arange(seq)

    body = _stack_body(cfg, positions, cache_pos, remat)
    u, n_units, rem = _unit_layout(cfg)

    if n_units > 0:
        def scan_fn(x, inp):
            unit_params, unit_state = inp
            x, ns = body(x, unit_params, unit_state)
            return x, ns

        xs = (params["unit"], cache["unit"] if cache is not None else None)
        if cache is None:
            # map None states through scan via a dummy per-step None pytree
            xs = (params["unit"], [None] * u)
            x, _ = jax.lax.scan(
                lambda c, p: (body(c, p, None)[0], ()), x, params["unit"]
            )
            new_unit_cache = None
        else:
            x, new_unit_cache = jax.lax.scan(scan_fn, x, xs)
    else:
        new_unit_cache = None if cache is None else []

    new_rem = []
    for j in range(rem):
        st = None if cache is None else cache["rem"][j]
        x, ns = _layer_apply(
            params["rem"][j], cfg, cfg.attn_pattern[j], x, positions, st, cache_pos
        )
        new_rem.append(ns)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"unit": new_unit_cache, "rem": new_rem}
    if return_hidden:
        return x, new_cache
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    else:
        logits = L.lm_head_apply(params, cfg, x)
    return logits, new_cache

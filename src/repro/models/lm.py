"""Decoder-only LM assembly: scan-over-layers with heterogeneous layer
patterns (dense / MoE / SWA / RG-LRU / SSD), KV caches for decode, and
modality-stub prefix embeddings (VLM).

Layers are grouped into repeating *units* (cfg.attn_pattern); the layer stack
is a ``lax.scan`` over units (keeps HLO size O(unit) instead of O(depth) —
essential for 61-layer compile times), with any remainder layers applied
unscanned so configs like RecurrentGemma's 38 = 12x(rglru,rglru,local)+2
lower with their exact depth.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L
from . import rglru as R
from . import ssd as S

Params = Any


# ---------------------------------------------------------------------------
# layer unit: init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": L.rmsnorm_init(cfg.d_model, jnp.float32)}
    if kind in ("global", "swa", "local"):
        p["attn"] = L.attention_init(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"] = R.rglru_block_init(ks[0], cfg)
    elif kind == "ssd":
        p["ssd"] = S.ssd_block_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "ssd":  # mamba2 blocks have no separate MLP
        p["norm2"] = L.rmsnorm_init(cfg.d_model, jnp.float32)
        if cfg.moe is not None:
            p["moe"] = L.moe_init(ks[1], cfg)
        else:
            p["mlp"] = L.mlp_init(ks[1], cfg)
    return p


def _layer_state_init(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    """Decode-time per-layer state."""
    if kind in ("global", "swa", "local"):
        # windowed attention uses a bounded ring buffer (this is what makes
        # long_500k decode O(window) for swa/local archs)
        cache_len = max_seq if kind == "global" else min(max_seq, cfg.window * 2)
        shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
        dt = jnp.dtype(cfg.compute_dtype)
        return {
            "k": jnp.zeros(shape, dt),
            "v": jnp.zeros(shape, dt),
            # per-slot position table: slots in a continuous-batching engine
            # advance independently (DESIGN.md §9)
            "pos": jnp.full((batch, cache_len), -1, jnp.int32),
        }
    if kind == "rglru":
        return R.rglru_init_state(cfg, batch)
    if kind == "ssd":
        return S.ssd_init_state(cfg, batch)
    raise ValueError(kind)


def _layer_apply(
    params: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    state=None,
    block_table=None,
):
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind in ("global", "swa", "local"):
        out, new_state = L.attention_apply(
            params["attn"], cfg, h, positions, kind=kind,
            cache=state, block_table=block_table,
        )
    elif kind == "rglru":
        out, new_state = R.rglru_block_apply(params["rglru"], cfg, h, state)
    elif kind == "ssd":
        out, new_state = S.ssd_block_apply(params["ssd"], cfg, h, state)
    else:
        raise ValueError(kind)
    x = x + out

    if kind != "ssd":
        h2 = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            x = x + L.moe_apply(params["moe"], cfg, h2)
        else:
            x = x + L.mlp_apply(params["mlp"], cfg, h2)
    return x, new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _unit_layout(cfg: ModelConfig):
    u = len(cfg.attn_pattern)
    n_units = cfg.n_layers // u
    rem = cfg.n_layers - n_units * u
    return u, n_units, rem


def lm_init(key, cfg: ModelConfig) -> Params:
    u, n_units, rem = _unit_layout(cfg)
    ks = jax.random.split(key, 3 + u * n_units + rem)
    params: dict = {}
    params.update(L.embed_init(ks[0], cfg))
    # stacked unit params: for each position j in the unit, leaves stacked
    # over n_units along a new leading axis
    unit = []
    ki = 3
    for j in range(u):
        per = [
            _layer_init(ks[ki + i * u + j], cfg, cfg.attn_pattern[j])
            for i in range(n_units)
        ]
        unit.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    params["unit"] = unit
    ki += u * n_units
    params["rem"] = [
        _layer_init(ks[ki + j], cfg, cfg.attn_pattern[j]) for j in range(rem)
    ]
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, jnp.float32)
    if not cfg.tie_embeddings:
        params.update(L.lm_head_init(ks[1], cfg))
    return params


def lm_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Any:
    u, n_units, rem = _unit_layout(cfg)
    unit = []
    for j in range(u):
        st = _layer_state_init(cfg, cfg.attn_pattern[j], batch, max_seq)
        unit.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (n_units,) + x.shape), st))
    remst = [
        _layer_state_init(cfg, cfg.attn_pattern[j], batch, max_seq)
        for j in range(rem)
    ]
    return {"unit": unit, "rem": remst}


def lm_init_paged_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    num_blocks: int,
    block_size: int,
    cache_dtype=None,
) -> Any:
    """Paged decode cache (DESIGN.md §9).

    Global-attention layers store K/V in a block pool of ``num_blocks``
    fixed-size blocks — (num_blocks, block_size, Hkv, Dh) per layer —
    addressed through ONE per-sequence block table ``"bt"`` (batch,
    max_seq // block_size; -1 = unassigned): token t of slot b lives at
    block ``bt[b, t // block_size]``, offset ``t % block_size``, in every
    layer's own pool. Capacity is bounded by tokens in flight (num_blocks *
    block_size), not batch * max_seq. Windowed ring buffers and recurrent
    states are already O(1)-bounded per slot and stay dense. ``cache_dtype``
    is the on-write quantization dtype (the serve cache codec's wire dtype);
    None keeps the compute dtype (identity, bit-exact vs dense).
    """
    assert max_seq % block_size == 0, (max_seq, block_size)
    u, n_units, rem = _unit_layout(cfg)
    dt = jnp.dtype(cache_dtype) if cache_dtype else jnp.dtype(cfg.compute_dtype)

    def st(kind):
        if kind == "global":
            shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
            return {
                "pk": jnp.zeros(shape, dt),
                "pv": jnp.zeros(shape, dt),
                "ppos": jnp.full((num_blocks, block_size), -1, jnp.int32),
            }
        return _layer_state_init(cfg, kind, batch, max_seq)

    unit = []
    for j in range(u):
        s0 = st(cfg.attn_pattern[j])
        unit.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_units,) + x.shape), s0
        ))
    remst = [st(cfg.attn_pattern[j]) for j in range(rem)]
    return {
        "unit": unit,
        "rem": remst,
        "bt": jnp.full((batch, max_seq // block_size), -1, jnp.int32),
    }


def _stack_body(cfg: ModelConfig, positions, remat: str, block_table=None):
    u = len(cfg.attn_pattern)

    def unit_body(x, unit_params, unit_state):
        new_states = []
        for j in range(u):
            st = None if unit_state is None else unit_state[j]
            x, ns = _layer_apply(
                unit_params[j], cfg, cfg.attn_pattern[j], x, positions, st,
                block_table,
            )
            new_states.append(ns)
        return x, new_states

    if remat == "full":
        unit_body = jax.checkpoint(unit_body)
    elif remat == "dots":
        unit_body = jax.checkpoint(
            unit_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    return unit_body


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                   # (B, S)
    prefix_embeds: Optional[jax.Array] = None,  # VLM stub: (B, Np, d)
    cache: Optional[Any] = None,
    cache_pos=None,                      # decode write position: scalar or (B,)
    remat: str = "none",
    return_hidden: bool = False,
):
    """Returns (logits-or-hidden, new_cache_or_None).

    ``cache_pos`` may be a per-slot (B,) vector (continuous batching): each
    row's tokens then sit at positions ``cache_pos[b] + arange(S)``; rows
    with ``cache_pos[b] < 0`` are frozen (cache writes dropped, outputs to
    be discarded by the caller). A paged cache carries its block table under
    a top-level ``"bt"`` key and is threaded to the attention layers here.
    """
    block_table = cache.get("bt") if isinstance(cache, dict) else None
    x = L.embed_apply(params, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    seq = x.shape[1]
    if cache_pos is None:
        positions = jnp.arange(seq)
    else:
        cp = jnp.asarray(cache_pos)
        if cp.ndim:
            # frozen rows (cp < 0): push far negative so every per-token
            # position cp + t stays negative, not just the first
            cp = jnp.where(cp < 0, jnp.int32(-(2 ** 30)), cp)
            positions = cp[:, None] + jnp.arange(seq)
        else:
            positions = cp + jnp.arange(seq)

    body = _stack_body(cfg, positions, remat, block_table)
    u, n_units, rem = _unit_layout(cfg)

    if n_units > 0:
        def scan_fn(x, inp):
            unit_params, unit_state = inp
            x, ns = body(x, unit_params, unit_state)
            return x, ns

        xs = (params["unit"], cache["unit"] if cache is not None else None)
        if cache is None:
            # map None states through scan via a dummy per-step None pytree
            xs = (params["unit"], [None] * u)
            x, _ = jax.lax.scan(
                lambda c, p: (body(c, p, None)[0], ()), x, params["unit"]
            )
            new_unit_cache = None
        else:
            x, new_unit_cache = jax.lax.scan(scan_fn, x, xs)
    else:
        new_unit_cache = None if cache is None else []

    new_rem = []
    for j in range(rem):
        st = None if cache is None else cache["rem"][j]
        x, ns = _layer_apply(
            params["rem"][j], cfg, cfg.attn_pattern[j], x, positions, st,
            block_table,
        )
        new_rem.append(ns)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"unit": new_unit_cache, "rem": new_rem}
        if block_table is not None:
            new_cache["bt"] = block_table
    if return_hidden:
        return x, new_cache
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    else:
        logits = L.lm_head_apply(params, cfg, x)
    return logits, new_cache

from .model import Model, build

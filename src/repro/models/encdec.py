"""Encoder-decoder transformer (SeamlessM4T-v2 backbone).

Encoder consumes precomputed frame embeddings (audio frontend stub, per the
assignment: ``input_specs()`` provides (B, S_src, d) frames). Decoder is a
causal transformer with cross-attention; decode mode carries self-attention
KV caches and reuses precomputed cross-attention K/V from the encoder pass.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L

Params = Any


def _enc_layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": L.attention_init(ks[0], cfg),
        "norm2": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "mlp": L.mlp_init(ks[1], cfg),
    }


def _dec_layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": L.attention_init(ks[0], cfg),
        "norm_x": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "xattn": L.attention_init(ks[1], cfg),
        "norm2": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "mlp": L.mlp_init(ks[2], cfg),
    }


def encdec_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4 + cfg.encoder_layers + cfg.n_layers)
    enc = [
        _enc_layer_init(ks[4 + i], cfg) for i in range(cfg.encoder_layers)
    ]
    dec = [
        _dec_layer_init(ks[4 + cfg.encoder_layers + i], cfg)
        for i in range(cfg.n_layers)
    ]
    params = {
        "enc_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "final_norm": L.rmsnorm_init(cfg.d_model, jnp.float32),
    }
    params.update(L.embed_init(ks[0], cfg))
    params.update(L.lm_head_init(ks[1], cfg))
    return params


def encode(params: Params, cfg: ModelConfig, frames: jax.Array, remat: str = "none"):
    """frames: (B, S_src, d) precomputed frontend embeddings."""
    positions = jnp.arange(frames.shape[1])

    def body(x, lp):
        h = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        out, _ = L.attention_apply(
            lp["attn"], cfg, h, positions, kind="global", causal=False
        )
        x = x + out
        h = L.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], cfg, h), ()

    if remat != "none":
        body = jax.checkpoint(body)
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x, _ = jax.lax.scan(body, x, params["enc_stack"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def cross_kv(params: Params, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute per-layer cross-attention K/V from encoder output."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = enc_out.shape
    hkv, dh = cfg.n_kv_heads, cfg.head_dim

    def per_layer(lp):
        k = (enc_out @ lp["xattn"]["wk"].astype(dt)).reshape(b, s, hkv, dh)
        v = (enc_out @ lp["xattn"]["wv"].astype(dt)).reshape(b, s, hkv, dh)
        return {"k": k, "v": v}

    return jax.vmap(per_layer)(params["dec_stack"])


def decode(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                 # (B, S_tgt)
    xkv: Any,                          # stacked {"k","v"} (L, B, S_src, Hkv, Dh)
    cache: Optional[Any] = None,       # self-attn caches (L-stacked)
    cache_pos=None,
    remat: str = "none",
):
    x = L.embed_apply(params, cfg, tokens)
    seq = x.shape[1]
    pos0 = 0 if cache_pos is None else cache_pos
    positions = pos0 + jnp.arange(seq)

    def body(x, inp):
        lp, lxkv, lcache = inp
        h = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        out, ns = L.attention_apply(
            lp["attn"], cfg, h, positions, kind="global", cache=lcache,
        )
        x = x + out
        h = L.rmsnorm(lp["norm_x"], x, cfg.norm_eps)
        # cross-attention: project q only; K/V precomputed from encoder
        out, _ = L.attention_apply(
            lp["xattn"], cfg, h, positions, kind="global",
            cross_kv=(lxkv["k"], lxkv["v"]), causal=False,
        )
        x = x + out
        h = L.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], cfg, h), ns

    if remat != "none":
        body = jax.checkpoint(body)

    if cache is None:
        x, _ = jax.lax.scan(lambda c, i: (body(c, (i[0], i[1], None))[0], ()),
                            x, (params["dec_stack"], xkv))
        new_cache = None
    else:
        x, new_cache = jax.lax.scan(body, x, (params["dec_stack"], xkv, cache))

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head_apply(params, cfg, x)
    return logits, new_cache


def encdec_init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    dt = jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.full((cfg.n_layers, batch, max_seq), -1, jnp.int32),
    }

"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)                      (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                      (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)            (learned decay, c=8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses a log-depth ``lax.associative_scan`` over time; decode
is the one-step recurrence with a carried (B, W) state. The full block is the
Griffin recurrent block: linear-in -> causal depthwise conv -> RG-LRU, gated
by a parallel GELU branch, linear-out.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

C_DECAY = 8.0


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _init_normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rglru_block_init(key, cfg: ModelConfig) -> Any:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    conv = cfg.rglru.d_conv
    ks = jax.random.split(key, 7)
    sc = 1.0 / math.sqrt(d)
    return {
        "w_in": _init_normal(ks[0], (d, w), sc, _pdtype(cfg)),
        "w_gate": _init_normal(ks[1], (d, w), sc, _pdtype(cfg)),
        "conv_w": _init_normal(ks[2], (conv, w), 1.0 / math.sqrt(conv), _pdtype(cfg)),
        "wa": _init_normal(ks[3], (w, w), 1.0 / math.sqrt(w), _pdtype(cfg)),
        "wx": _init_normal(ks[4], (w, w), 1.0 / math.sqrt(w), _pdtype(cfg)),
        # Lambda parametrized so a ~ U[0.9, 0.999] at init (paper App. A)
        "lam": jax.random.uniform(ks[5], (w,), jnp.float32, 0.7, 1.3),
        "w_out": _init_normal(ks[6], (w, d), 1.0 / math.sqrt(w), _pdtype(cfg)),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array]):
    """x: (B, S, W); w: (K, W). Returns (y, new_state) with causal padding.

    state (decode): (B, K-1, W) trailing inputs from previous steps."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # depthwise conv as sum of shifted scalings (k is tiny: 4)
    s_out = x.shape[1]
    y = sum(xp[:, i : i + s_out, :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    return y, new_state


def rglru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array]) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t via associative scan over axis 1 (time)."""

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_apply(
    params: Any,
    cfg: ModelConfig,
    x: jax.Array,                 # (B, S, d)
    state: Optional[dict] = None,  # decode: {"h": (B,W), "conv": (B,K-1,W)}
):
    dt = _dtype(cfg)
    x = x.astype(dt)
    gate = jax.nn.gelu(x @ params["w_gate"].astype(dt), approximate=True)
    u = x @ params["w_in"].astype(dt)
    u, conv_state = _causal_depthwise_conv(
        u, params["conv_w"].astype(dt), None if state is None else state["conv"]
    )

    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u32 @ params["wa"].astype(jnp.float32))
    i = jax.nn.sigmoid(u32 @ params["wx"].astype(jnp.float32))
    log_a = -C_DECAY * jax.nn.softplus(params["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * u32)

    if state is None or x.shape[1] > 1:
        h0 = None if state is None else state["h"]
        h = rglru_scan(a, b, h0)
    else:
        h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
        h = h[:, None, :]

    new_state = {"h": h[:, -1, :], "conv": conv_state}
    y = (h.astype(dt) * gate) @ params["w_out"].astype(dt)
    return y, new_state


def rglru_init_state(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rglru.lru_width or cfg.d_model
    k = cfg.rglru.d_conv
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, k - 1, w), _dtype(cfg)),
    }

"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked "discrete dual" form (paper Listing 1): sequence split into chunks of
Q; within a chunk the output is a masked (causal, decay-weighted) quadratic
contraction; across chunks the SSM state h in R^{H x P x N} is carried by a
linear recurrence (implemented with lax.scan — the cross-chunk loop is short:
S/Q steps). Decode is the O(1) recurrent update.

A Pallas kernel for the intra-chunk contraction lives in
``repro.kernels.ssd_scan`` with this file's `ssd_chunked` as its oracle.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _init_normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def ssd_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = cfg.d_model * s.expand
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def ssd_block_init(key, cfg: ModelConfig) -> Any:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h = ssd_dims(cfg)
    n, g = s.d_state, s.n_groups
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    # fused input projection: [x (d_inner), z gate (d_inner), B (g*n), C (g*n), dt (h)]
    proj_out = 2 * d_inner + 2 * g * n + h
    return {
        "w_in": _init_normal(ks[0], (d, proj_out), sc, _pdtype(cfg)),
        "conv_w": _init_normal(
            ks[1], (s.d_conv, d_inner + 2 * g * n), 0.5, _pdtype(cfg)
        ),
        "a_log": jnp.log(jax.random.uniform(ks[2], (h,), jnp.float32, 1.0, 16.0)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), _pdtype(cfg)),
        "w_out": _init_normal(ks[5], (d_inner, d), 1.0 / math.sqrt(d_inner), _pdtype(cfg)),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """Stable 'segment sum': L[..., i, j] = sum_{j < m <= i} a[..., m], with
    -inf above the diagonal. a: (..., Q) -> (..., Q, Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii, jj = jnp.meshgrid(jnp.arange(q), jnp.arange(q), indexing="ij")
    return jnp.where(ii[..., :, :] >= jj[..., :, :], diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)   softplus'd step sizes
    a_log: jax.Array,  # (H,)
    b: jax.Array,      # (B, S, G, N)
    c: jax.Array,      # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
):
    """Chunked SSD. Returns (y: (B,S,H,P), h_final: (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk
    rep = h // g

    da = (-jnp.exp(a_log))[None, None, :] * dt            # (B, S, H) log-decay
    xr = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    br = b.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cr = c.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    dtr = dt.reshape(bsz, nc, chunk, h)
    dar = da.reshape(bsz, nc, chunk, h)

    # intra-chunk (diagonal) term
    lmat = jnp.exp(_segsum(dar.transpose(0, 1, 3, 2)))     # (B, nc, H, Q, Q)
    cb = jnp.einsum("bzqgn,bzkgn->bzgqk", cr, br)          # (B, nc, G, Q, Q)
    cb = jnp.repeat(cb, rep, axis=2)                       # (B, nc, H, Q, Q)
    y_diag = jnp.einsum("bzhij,bzjh,bzjhp->bzihp", cb * lmat, dtr, xr)

    # per-chunk final states (B expanded from groups to heads)
    cum = jnp.cumsum(dar, axis=2)
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)        # (B, nc, Q, H)
    brh = jnp.repeat(br, rep, axis=3)                      # (B, nc, Q, H, N)
    states = jnp.einsum(
        "bzqhn,bzqh,bzqhp->bzhpn", brh, decay_states * dtr, xr
    )                                                       # (B, nc, H, P, N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B, nc, H)

    def scan_body(hprev, inp):
        st, dec = inp                                      # (B,H,P,N), (B,H)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    hinit = (
        jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    hfin, hprevs = jax.lax.scan(
        scan_body,
        hinit,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)               # (B, nc, H, P, N)

    # off-diagonal (state) contribution
    state_decay = jnp.exp(cum)                             # (B, nc, Q, H)
    y = (y_diag + _y_off_grouped(cr, hprevs, state_decay, rep)).reshape(bsz, s, h, p)
    return y, hfin


def _y_off_grouped(cr, hprevs, state_decay, rep):
    """Grouped C: (B,nc,Q,G,N) x states (B,nc,H,P,N) -> (B,nc,Q,H,P)."""
    ch = jnp.repeat(cr, rep, axis=3)  # (B, nc, Q, H, N)
    return jnp.einsum("bzqhn,bzhpn,bzqh->bzqhp", ch, hprevs, state_decay)


def ssd_step(
    x: jax.Array,      # (B, 1, H, P)
    dt: jax.Array,     # (B, 1, H)
    a_log: jax.Array,
    b: jax.Array,      # (B, 1, G, N)
    c: jax.Array,      # (B, 1, G, N)
    h0: jax.Array,     # (B, H, P, N)
):
    """O(1) recurrent decode step."""
    hnum = x.shape[2]
    g = b.shape[2]
    rep = hnum // g
    da = jnp.exp((-jnp.exp(a_log))[None, :] * dt[:, 0])    # (B, H)
    bh = jnp.repeat(b[:, 0], rep, axis=1)                  # (B, H, N)
    ch = jnp.repeat(c[:, 0], rep, axis=1)
    upd = jnp.einsum(
        "bhn,bh,bhp->bhpn", bh.astype(jnp.float32), dt[:, 0], x[:, 0].astype(jnp.float32)
    )
    hnew = h0 * da[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", ch.astype(jnp.float32), hnew)
    return y[:, None], hnew


def ssd_block_apply(
    params: Any,
    cfg: ModelConfig,
    xin: jax.Array,                 # (B, S, d)
    state: Optional[dict] = None,   # decode: {"h": (B,H,P,N), "conv": (B,K-1,C)}
    use_kernel: bool = False,
):
    s = cfg.ssm
    dt_ = _dtype(cfg)
    bsz, seq, _ = xin.shape
    d_inner, h = ssd_dims(cfg)
    g, n, p = s.n_groups, s.d_state, s.head_dim

    proj = xin.astype(dt_) @ params["w_in"].astype(dt_)
    x, z, bmat, cmat, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + g * n, 2 * d_inner + 2 * g * n],
        axis=-1,
    )

    # causal depthwise conv over concat([x, B, C])
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)
    k = s.d_conv
    if state is None:
        cpad = jnp.pad(conv_in, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        cpad = jnp.concatenate([state["conv"].astype(conv_in.dtype), conv_in], 1)
    w = params["conv_w"].astype(dt_)
    conv = sum(cpad[:, i : i + seq, :] * w[i][None, None, :] for i in range(k))
    conv = jax.nn.silu(conv)
    new_conv_state = cpad[:, -(k - 1):, :]
    x, bmat, cmat = jnp.split(conv, [d_inner, d_inner + g * n], axis=-1)

    xh = x.reshape(bsz, seq, h, p)
    bh = bmat.reshape(bsz, seq, g, n)
    ch = cmat.reshape(bsz, seq, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    if state is not None and seq == 1:
        y, hfin = ssd_step(xh, dt, params["a_log"], bh, ch, state["h"])
    else:
        h0 = None if state is None else state["h"]
        if use_kernel:
            from repro.kernels.ssd_scan import ops as ssd_ops

            y, hfin = ssd_ops.ssd_chunked(
                xh, dt, params["a_log"], bh, ch, s.chunk_size, h0
            )
        else:
            y, hfin = ssd_chunked(xh, dt, params["a_log"], bh, ch, s.chunk_size, h0)

    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, seq, d_inner)
    # gated RMS norm (Mamba-2 uses normalization before out-proj)
    y32 = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y32 = y32 * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"].astype(jnp.float32)
    out = y32.astype(dt_) @ params["w_out"].astype(dt_)
    new_state = {"h": hfin, "conv": new_conv_state}
    return out, new_state


def ssd_init_state(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    d_inner, h = ssd_dims(cfg)
    return {
        "h": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros(
            (batch, s.d_conv - 1, d_inner + 2 * s.n_groups * s.d_state), _dtype(cfg)
        ),
    }

"""The paper's own experiment models (Section 5.1), in pure JAX.

- ``fc_mnist``: two-layer fully-connected net, 512 hidden units, 10 classes.
- ``cnn_cifar``: ResNet-style CNN (3 stages x 2 basic blocks, GroupNorm in
  place of BatchNorm so the model stays stateless/pure).

Both are used by the paper-reproduction benchmarks (Tables 2-3, Figs 2-6) to
compare SGD / Sparse / LASG / SASG.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Any


def _dense_init(key, din, dout):
    k1, k2 = jax.random.split(key)
    lim = 1.0 / math.sqrt(din)
    return {
        "w": jax.random.uniform(k1, (din, dout), jnp.float32, -lim, lim),
        "b": jnp.zeros((dout,), jnp.float32),
    }


def fc_init(key, cfg: ModelConfig, input_dim: int = 784) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "fc1": _dense_init(k1, input_dim, cfg.d_model),
        "fc2": _dense_init(k2, cfg.d_model, cfg.vocab_size),
    }


def fc_apply(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


# ---------------------------------------------------------------------------
# compact ResNet (CIFAR)
# ---------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
            * math.sqrt(2.0 / fan_in))


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _gn(x, params, groups=8):
    b, h, w, c = x.shape
    xg = x.reshape(b, h, w, groups, c // groups)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(b, h, w, c) * params["scale"] + params["bias"]


def _gn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def _block_init(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, 3, cin, cout), "gn1": _gn_init(cout),
        "conv2": _conv_init(ks[1], 3, 3, cout, cout), "gn2": _gn_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(_gn(_conv(x, p["conv1"], stride), p["gn1"]))
    h = _gn(_conv(h, p["conv2"]), p["gn2"])
    skip = _conv(x, p["proj"], stride) if "proj" in p else x
    return jax.nn.relu(h + skip)


# Depth of the homogeneous full-width trunk (the stage-1 basic blocks, which
# share activation shape and param structure). They are stored stacked on a
# leading axis so dist.sharding / dist.pipeline can stage-shard them; the
# downsampling stages (stride-2 boundaries change the activation shape, so
# they cannot ride a homogeneous GPipe ring) stay flat per-block leaves.
CNN_TRUNK_DEPTH = 2


def cnn_init(key, cfg: ModelConfig, in_ch: int = 3) -> Params:
    c = cfg.d_model  # base width (64)
    ks = jax.random.split(key, 9)
    trunk = [_block_init(ks[1 + l], c, c, 1) for l in range(CNN_TRUNK_DEPTH)]
    return {
        "stem": _conv_init(ks[0], 3, 3, in_ch, c), "gn0": _gn_init(c),
        "trunk": jax.tree.map(lambda *xs: jnp.stack(xs), *trunk),
        "s2b1": _block_init(ks[3], c, 2 * c, 2), "s2b2": _block_init(ks[4], 2 * c, 2 * c, 1),
        "s3b1": _block_init(ks[5], 2 * c, 4 * c, 2), "s3b2": _block_init(ks[6], 4 * c, 4 * c, 1),
        "head": _dense_init(ks[7], 4 * c, cfg.vocab_size),
    }


def cnn_stem(params: Params, x: jax.Array) -> jax.Array:
    return jax.nn.relu(_gn(_conv(x, params["stem"]), params["gn0"]))


def cnn_trunk_block(block_params: Params, h: jax.Array) -> jax.Array:
    """One full-width (stride-1) trunk block — the pipeline layer_fn."""
    return _block_apply(block_params, h, 1)


def cnn_head(params: Params, h: jax.Array) -> jax.Array:
    h = _block_apply(params["s2b1"], h, 2)
    h = _block_apply(params["s2b2"], h, 1)
    h = _block_apply(params["s3b1"], h, 2)
    h = _block_apply(params["s3b2"], h, 1)
    h = h.mean(axis=(1, 2))
    return h @ params["head"]["w"] + params["head"]["b"]


def cnn_apply(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = cnn_stem(params, x)
    for l in range(CNN_TRUNK_DEPTH):
        h = cnn_trunk_block(jax.tree.map(lambda w: w[l], params["trunk"]), h)
    return cnn_head(params, h)

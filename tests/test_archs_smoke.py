"""Per-architecture smoke tests (deliverable f): REDUCED config of each
assigned family — one forward/train step on CPU asserting shapes + no NaNs,
plus a decode step exercising the serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build


def _batch_for(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    if cfg.is_encdec:
        ss = S // 2
        return {
            "frames": jnp.asarray(rng.normal(size=(B, ss, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, ss)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, ss)), jnp.int32),
        }
    if cfg.frontend == "patch_embed":
        np_tok = 8
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - np_tok)), jnp.int32),
            "patch_embeds": jnp.asarray(rng.normal(size=(B, np_tok, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - np_tok)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch} zero/NaN grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    if model.decode_step is None:
        pytest.skip("paper model: no decode")
    params = model.init(jax.random.PRNGKey(0))
    B, S_max = 2, 32
    cache = model.init_cache(B, S_max)
    toks = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, toks, jnp.asarray(4))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits)).all(), f"{arch} decode NaN"
    # cache must actually change
    diff = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2))
    )
    assert diff > 0


def test_decode_matches_parallel_forward_mamba2():
    """Step-by-step SSD decode == chunked parallel forward (duality check)."""
    cfg = get_config("mamba2_370m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S = 1, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    from repro.models import lm as LM

    full_logits, _ = LM.lm_forward(params, cfg, toks)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], jnp.asarray(t))
        outs.append(np.asarray(lg[:, 0]))
    step_logits = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        step_logits, np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_decode_matches_parallel_forward_attention():
    """Decode-with-cache == full causal forward for a GQA attention arch."""
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    B, S = 1, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    from repro.models import lm as LM

    full_logits, _ = LM.lm_forward(params, cfg, toks)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], jnp.asarray(t))
        outs.append(np.asarray(lg[:, 0]))
    step_logits = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        step_logits, np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_swa_equals_global_within_window():
    """SWA == full attention while the sequence fits in the window."""
    from dataclasses import replace

    base = get_config("mixtral_8x7b").reduced()
    cfg_swa = replace(base, window=64)      # S=16 < window
    cfg_glob = replace(base, attn_pattern=("global",))
    m1, m2 = build(cfg_swa), build(cfg_glob)
    params = m1.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 16)), jnp.int32)
    from repro.models import lm as LM

    l1, _ = LM.lm_forward(params, cfg_swa, toks)
    l2, _ = LM.lm_forward(params, cfg_glob, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3, atol=1e-3)


def test_paper_models_train():
    from repro.data import synthetic_classification

    for name, shape, lr in [("fc_mnist", (28, 28, 1), 0.05),
                            ("cnn_cifar", (32, 32, 3), 0.01)]:
        cfg = get_config(name)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        x, y = synthetic_classification(64, cfg.vocab_size, shape, seed=0)
        batch = {"x": jnp.asarray(x), "labels": jnp.asarray(y)}
        loss0 = float(model.loss_fn(params, batch))
        step = jax.jit(lambda p, b: jax.tree.map(
            lambda q, g: q - lr * g, p, jax.grad(model.loss_fn)(p, b)))
        for _ in range(3):
            params = step(params, batch)
        loss1 = float(model.loss_fn(params, batch))
        assert np.isfinite(loss1) and loss1 < loss0, name

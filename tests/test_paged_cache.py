"""Paged KV cache tests: block allocator, slot lifecycle ops, paged-vs-
dense bit-exactness, quantized cache-block parity tolerance, freed-block
reuse hygiene, and sharding specs for the pool leaves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.sharding import cache_specs
from repro.models import build
from repro.serve import (
    BatchedServer,
    BlockAllocator,
    Request,
    build_serve,
    cache_bytes,
    release_blocks,
    reset_slots,
)


# -- allocator ------------------------------------------------------------

def test_allocator_roundtrip_and_high_water():
    al = BlockAllocator(num_blocks=6, block_size=8)
    assert al.blocks_for(1) == 1 and al.blocks_for(8) == 1
    assert al.blocks_for(9) == 2
    a = al.allocate(3)
    b = al.allocate(2)
    assert al.used_blocks == 5 and al.high_water == 5
    assert len(set(a) | set(b)) == 5
    al.free(a)
    assert al.used_blocks == 2
    assert al.high_water == 5  # high-water never decays
    assert not al.can_allocate(5)
    with pytest.raises(RuntimeError, match="exhausted"):
        al.allocate(5)
    c = al.allocate(4)
    assert set(c) <= set(range(6)) and not set(c) & set(b)


# -- slot lifecycle ops on a toy cache tree -------------------------------

def _toy_cache():
    return {
        "unit": [{
            "k": jnp.ones((2, 3, 4, 1, 2)),          # (units, B, S, H, D)
            "pos": jnp.ones((2, 3, 4), jnp.int32),
            "h": jnp.ones((2, 3, 5)),                # recurrent state
        }],
        "rem": [{
            "pk": jnp.ones((6, 2, 1, 2)),            # (NB, bs, H, D)
            "ppos": jnp.ones((6, 2), jnp.int32),
        }],
        "bt": jnp.ones((3, 3), jnp.int32),
    }


def test_reset_slots_masks_pos_and_recurrent_only():
    c = reset_slots(_toy_cache(), jnp.asarray([True, False, True]))
    u = c["unit"][0]
    np.testing.assert_array_equal(np.asarray(u["pos"][:, 1]), 1)
    np.testing.assert_array_equal(np.asarray(u["pos"][:, 0]), -1)
    np.testing.assert_array_equal(np.asarray(u["pos"][:, 2]), -1)
    np.testing.assert_array_equal(np.asarray(u["h"][:, 0]), 0.0)
    np.testing.assert_array_equal(np.asarray(u["h"][:, 1]), 1.0)
    # dense K/V and the paged pools are untouched (unreachable via pos)
    np.testing.assert_array_equal(np.asarray(u["k"]), 1.0)
    np.testing.assert_array_equal(np.asarray(c["rem"][0]["ppos"]), 1)
    np.testing.assert_array_equal(np.asarray(c["bt"]), 1)


def test_release_blocks_poisons_ppos_rows():
    c = release_blocks(_toy_cache(), jnp.asarray([1, 4, 6, 6]))  # 6 = OOB pad
    pp = np.asarray(c["rem"][0]["ppos"])
    np.testing.assert_array_equal(pp[[1, 4]], -1)
    np.testing.assert_array_equal(pp[[0, 2, 3, 5]], 1)
    # values and tables untouched
    np.testing.assert_array_equal(np.asarray(c["rem"][0]["pk"]), 1.0)


# -- paged == dense on the engine, and memory never above dense -----------

def _run_stream(serve, params, cfg, n_req, **kw):
    srv = BatchedServer(serve, params, cfg, batch_size=2, max_seq=32, **kw)
    rng = np.random.default_rng(3)
    for uid in range(n_req):
        srv.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, 10))).astype(np.int32),
            max_new_tokens=4,
        ))
    done, pending = srv.drain(strict=True)
    assert not pending
    return {r["uid"]: r["tokens"] for r in done}, srv


def test_paged_matches_dense_bitexact(mesh2d):
    """Same request stream, dense vs paged engine: identical tokens on the
    identity cache dtype, and the paged pool's byte high-water stays at or
    below the dense-equivalent cache (the BENCH_serve acceptance claim)."""
    cfg = get_config("internvl2_2b").reduced()
    model = build(cfg)
    serve = build_serve(model, mesh2d, fsdp="data", tp="model")
    params = jax.jit(model.init, out_shardings=serve.param_shardings)(
        jax.random.PRNGKey(0)
    )
    dense, _ = _run_stream(serve, params, cfg, 5, paged=False)
    paged, srv = _run_stream(serve, params, cfg, 5, paged=True, block_size=8)
    assert dense == paged
    st = srv.cache_stats()
    assert st["high_water_bytes"] <= st["dense_equiv_bytes"]
    assert st["block_high_water"] <= srv.allocator.num_blocks


def test_paged_small_pool_recycles_blocks_cleanly(mesh2d):
    """A pool sized for only 2 in-flight requests forces every later request
    through recycled blocks; outputs must still equal the dense run (freed
    blocks are position-poisoned, so no stale reads)."""
    cfg = get_config("internvl2_2b").reduced()
    model = build(cfg)
    serve = build_serve(model, mesh2d, fsdp="data", tp="model")
    params = jax.jit(model.init, out_shardings=serve.param_shardings)(
        jax.random.PRNGKey(0)
    )
    dense, _ = _run_stream(serve, params, cfg, 6, paged=False)
    # 32-token rows at block 8 -> 4 blocks/slot max; give the pool exactly
    # that for 2 slots so admissions contend for blocks
    paged, srv = _run_stream(serve, params, cfg, 6, paged=True,
                             block_size=8, num_blocks=8)
    assert dense == paged
    assert srv.allocator.free_blocks == 8  # all returned after drain


def test_quantized_cache_blocks_parity_tolerance():
    """bf16 cache blocks (quantize-on-write wire dtype) stay within a loose
    relative tolerance of the f32 decode chain — the gate that must pass
    before a narrower cache dtype is allowed off the identity default."""
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    B, S, N = 2, 8, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + N)), jnp.int32)

    def chain(cache_dtype):
        cache = model.init_paged_cache(B, 16, num_blocks=4, block_size=8,
                                       cache_dtype=cache_dtype)
        bt = np.full((B, 2), -1, np.int32)
        bt[0], bt[1] = [0, 1], [2, 3]
        cache["bt"] = jnp.asarray(bt)
        pos = jnp.zeros((B,), jnp.int32)
        logits, cache = model.decode_step(params, cache, toks[:, :S], pos)
        outs = [logits]
        for t in range(S, S + N):
            logits, cache = model.decode_step(
                params, cache, toks[:, t:t + 1], jnp.full((B,), t, jnp.int32))
            outs.append(logits)
        return np.asarray(jnp.concatenate(outs, axis=1)), cache

    f32, cache32 = chain(None)
    bf16, cache16 = chain("bfloat16")
    pools = [x for kp, x in jax.tree_util.tree_flatten_with_path(cache16)[0]
             if getattr(kp[-1], "key", None) in ("pk", "pv")]
    assert pools and all(x.dtype == jnp.bfloat16 for x in pools)
    assert cache_bytes(cache16) < cache_bytes(cache32)
    np.testing.assert_allclose(bf16, f32, atol=0.15, rtol=0.15)


def test_cache_specs_paged_pools(mesh2d):
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    cache = jax.eval_shape(
        lambda: model.init_paged_cache(4, 32, num_blocks=8, block_size=8)
    )
    cs = cache_specs(cache, mesh2d, "data", "model")
    flat = jax.tree_util.tree_flatten_with_path(cs)[0]
    by_key = {}
    for kp, v in flat:
        by_key.setdefault(str(kp).split("'")[-2], []).append(tuple(v))
    # pool dim over data, head dim over tp (a stacked unit layout shifts the
    # pool dim right by one); tables replicated
    for spec in by_key["pk"] + by_key["pv"]:
        assert "data" in spec[:2] and spec[-2] == "model"
    for spec in by_key["ppos"] + by_key["bt"]:
        assert spec == ()

"""Continuous-batching engine tests: slot recycling isolation, drain
semantics, scheduler planning, and prefill/decode parity per serveable
arch family (DESIGN.md §9 parity contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serve import BatchedServer, Request, Scheduler, build_serve
from repro.serve.scheduler import DECODE, PREFILL


def _mk(arch, mesh):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    serve = build_serve(model, mesh, fsdp="data", tp="model")
    params = jax.jit(model.init, out_shardings=serve.param_shardings)(
        jax.random.PRNGKey(0)
    )
    return cfg, model, serve, params


def _req(cfg, rng, uid, plen, max_new=4):
    return Request(
        uid=uid,
        prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
        max_new_tokens=max_new,
    )


# -- satellite 1: recycled slots must not read the previous occupant ------

@pytest.mark.parametrize("arch", ["internvl2_2b", "recurrentgemma_9b"])
def test_recycled_slot_matches_fresh_engine(mesh2d, arch):
    """A request served through a recycled slot (previous occupant's cache
    rows still on device) generates exactly the tokens a fresh engine
    generates for it alone — per-slot positions + slot reset make the old
    cache unreachable. recurrentgemma additionally exercises the
    recurrent-state (h/conv) zeroing on recycle."""
    cfg, model, serve, params = _mk(arch, mesh2d)
    rng = np.random.default_rng(7)
    first = _req(cfg, rng, 0, 9, max_new=6)
    second = _req(cfg, rng, 1, 5, max_new=6)

    srv = BatchedServer(serve, params, cfg, batch_size=1, max_seq=32)
    srv.submit(first)
    srv.submit(second)  # queued; admitted into slot 0 after `first` completes
    done, pending = srv.drain(max_ticks=200)
    assert not pending and len(done) == 2
    recycled = {r["uid"]: r["tokens"] for r in done}[1]

    # regenerate the same prompt stream: first rng draw is `first`'s prompt
    rng2 = np.random.default_rng(7)
    _ = _req(cfg, rng2, 0, 9, max_new=6)
    fresh = BatchedServer(serve, params, cfg, batch_size=1, max_seq=32)
    fresh.submit(_req(cfg, rng2, 1, 5, max_new=6))
    done_f, _ = fresh.drain(max_ticks=200)
    assert recycled == done_f[0]["tokens"]


# -- satellite 2: drain never silently truncates --------------------------

def test_drain_returns_completed_and_pending(mesh2d):
    cfg, model, serve, params = _mk("internvl2_2b", mesh2d)
    rng = np.random.default_rng(0)
    srv = BatchedServer(serve, params, cfg, batch_size=2, max_seq=32)
    for uid in range(4):
        srv.submit(_req(cfg, rng, uid, 4, max_new=8))
    done, pending = srv.drain(max_ticks=3)  # far too few ticks
    assert len(done) + len(pending) == 4
    assert pending, "a 3-tick drain cannot finish 4 requests"
    # the same engine finishes the remainder on a follow-up drain
    done2, pending2 = srv.drain(max_ticks=500)
    assert not pending2 and len(done2) == 4


def test_drain_strict_raises(mesh2d):
    cfg, model, serve, params = _mk("internvl2_2b", mesh2d)
    rng = np.random.default_rng(0)
    srv = BatchedServer(serve, params, cfg, batch_size=2, max_seq=32)
    for uid in range(4):
        srv.submit(_req(cfg, rng, uid, 4, max_new=8))
    with pytest.raises(RuntimeError, match="unfinished"):
        srv.drain(max_ticks=3, strict=True)


def test_submit_rejects_oversized_request(mesh2d):
    cfg, model, serve, params = _mk("internvl2_2b", mesh2d)
    srv = BatchedServer(serve, params, cfg, batch_size=2, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        srv.submit(Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                           max_new_tokens=8))  # 12 + 8 - 1 > 16
    srv.submit(Request(uid=1, prompt=np.arange(9, dtype=np.int32),
                       max_new_tokens=8))      # 9 + 8 - 1 == 16: fits
    done, pending = srv.drain(strict=True)
    assert len(done) == 1 and not pending


def test_submit_backpressure_at_max_queue(mesh2d):
    cfg, model, serve, params = _mk("internvl2_2b", mesh2d)
    srv = BatchedServer(serve, params, cfg, batch_size=1, max_seq=32,
                        max_queue=2)
    rng = np.random.default_rng(0)
    assert srv.submit(_req(cfg, rng, 0, 4))
    assert srv.submit(_req(cfg, rng, 1, 4))
    assert not srv.submit(_req(cfg, rng, 2, 4))  # queue full
    done, pending = srv.drain(strict=True)
    assert len(done) == 2


# -- scheduler unit tests (host-only, no model) ---------------------------

def _sched_with_slot(plen, max_new=4, widths=(8, 4, 2, 1)):
    s = Scheduler(batch_size=2, max_seq=64, widths=widths)
    s.submit(Request(uid=0, prompt=np.arange(plen, dtype=np.int32),
                     max_new_tokens=max_new))
    s.admit()
    return s


def test_scheduler_chunked_prefill_widths():
    """Prompt of 13 under widths (8,4,2,1): chunks of 8, 4, then the final
    token at width 1 — which completes prefill and consumes the sample."""
    s = _sched_with_slot(13)
    widths = []
    while s.slots[0] and s.slots[0].state == PREFILL:
        p = s.plan()
        widths.append(p.width)
        s.apply(p, np.array([5, 5]))
    assert widths == [8, 4, 1]
    assert s.slots[0].state == DECODE and s.slots[0].generated == [5]


def test_scheduler_interleaves_decode_between_chunks():
    """A decoding slot is frozen during a chunked tick but MUST run on the
    very next tick (fairness flag): a long admitted prompt cannot starve it."""
    s = Scheduler(batch_size=2, max_seq=64, widths=(8, 4, 2, 1))
    s.submit(Request(uid=0, prompt=np.arange(2, dtype=np.int32),
                     max_new_tokens=8))
    s.admit()
    for _ in range(3):  # finish uid 0's prefill, start decoding
        s.apply(s.plan(), np.array([1, 1]))
    assert s.slots[0].state == DECODE
    s.submit(Request(uid=1, prompt=np.arange(24, dtype=np.int32),
                     max_new_tokens=4))
    s.admit()
    p1 = s.plan()             # chunked prefill for the new long prompt
    assert p1.width == 8 and p1.pos[0] == -1 and 1 in p1.active
    s.apply(p1, np.array([1, 1]))
    p2 = s.plan()             # fairness: the decode slot goes next
    assert p2.width == 1 and 0 in p2.active
    s.apply(p2, np.array([1, 1]))
    p3 = s.plan()             # then chunking resumes
    assert p3.width == 8


def test_scheduler_admission_is_fifo_and_all_or_nothing():
    from repro.serve import BlockAllocator

    alloc = BlockAllocator(num_blocks=3, block_size=8)
    s = Scheduler(batch_size=3, max_seq=64, widths=(1,), allocator=alloc)
    s.submit(Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                     max_new_tokens=7))   # 16 tokens -> 2 blocks
    s.submit(Request(uid=1, prompt=np.arange(10, dtype=np.int32),
                     max_new_tokens=7))   # 2 blocks: does not fit
    s.submit(Request(uid=2, prompt=np.arange(4, dtype=np.int32),
                     max_new_tokens=2))   # 1 block: would fit, but FIFO
    assert s.admit() == [0]
    assert alloc.used_blocks == 2
    # head of queue can't get its blocks -> nothing behind it is admitted
    assert s.admit() == []
    assert [r.uid for r in s.queue] == [1, 2]


# -- satellite 3: prefill/decode parity per arch family -------------------

def _parity_case(cfg, S, N):
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    B = 2
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + N)), jnp.int32)
    from repro.models import lm as LM

    full, _ = LM.lm_forward(params, cfg, toks)

    # chunked prefill (one S-wide chunk), then N single-token decode steps
    # driven by per-slot position vectors — the engine's exact access pattern
    cache = model.init_cache(B, S + N)
    pos = jnp.zeros((B,), jnp.int32)
    logits, cache = model.decode_step(params, cache, toks[:, :S], pos)
    steps = [logits]
    for t in range(S, S + N):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1], pos)
        steps.append(logits)
    chained = jnp.concatenate(steps, axis=1)
    return np.asarray(full), np.asarray(chained)


@pytest.mark.parametrize("arch", ["llama3_8b", "internvl2_2b"])
def test_parity_attention_bitexact(arch):
    """Attention archs: chunked prefill + decode chain is BIT-EXACT vs the
    full-sequence forward on the identity cache dtype (the single-block
    flash formulation in layers._attend_masked equals one chunk of the
    chunked-softmax prefill path bitwise)."""
    full, chained = _parity_case(get_config(arch).reduced(), S=8, N=4)
    np.testing.assert_array_equal(full, chained)


def test_parity_ssd_close():
    """SSD parity is bounded by scan reassociation between the chunked
    (width = ssm chunk) and stepwise recurrences, not bit-exact. The scan
    chunk is shrunk so both the prefill width (S) and the full sequence
    (S + N) are chunk multiples — ssd_chunked asserts divisibility."""
    import dataclasses

    cfg = get_config("mamba2_370m").reduced()
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk_size=4))
    full, chained = _parity_case(cfg, S=8, N=4)
    np.testing.assert_allclose(full, chained, atol=1e-4, rtol=1e-4)


def test_parity_rglru_close():
    full, chained = _parity_case(get_config("recurrentgemma_9b").reduced(),
                                 S=8, N=4)
    np.testing.assert_allclose(full, chained, atol=1e-4, rtol=1e-4)


def test_moe_engine_completes(mesh2d):
    """MoE archs route per-batch capacity groups, so decode ticks and
    full-sequence batches drop different tokens — no full-forward parity
    claim; the engine contract is completion with in-vocab tokens."""
    cfg, model, serve, params = _mk("mixtral_8x7b", mesh2d)
    rng = np.random.default_rng(0)
    srv = BatchedServer(serve, params, cfg, batch_size=2, max_seq=32)
    assert not srv.paged  # swa-only pattern: nothing to page
    for uid in range(3):
        srv.submit(_req(cfg, rng, uid, 5, max_new=3))
    done, pending = srv.drain(strict=True)
    assert len(done) == 3 and not pending
    assert all(0 <= t < cfg.vocab_size for r in done for t in r["tokens"])

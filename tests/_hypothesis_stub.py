"""Minimal deterministic stand-in for ``hypothesis``.

Loaded by conftest.py ONLY when the real package is unavailable (the test
image cannot install new dependencies). Implements exactly the surface the
property tests use — ``@given`` + ``@settings`` with ``integers`` /
``floats`` / ``sampled_from`` / ``booleans`` / ``tuples`` strategies — by
running ``max_examples``
seeded pseudo-random cases per test. No shrinking, no database, no phases:
a falsifying example is reported verbatim and the run fails.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example_from(self, rng: random.Random):
        return self._sample(rng)


class _StrategiesModule:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda r: tuple(s.example_from(r) for s in strats))


strategies = _StrategiesModule()


class settings:
    """Decorator recording ``max_examples`` for the enclosing ``@given``."""

    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(**strats):
    def decorate(fn):
        def wrapper():
            n = getattr(fn, "_stub_max_examples", 20)
            # per-test deterministic seed so failures reproduce across runs
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                kwargs = {k: s.example_from(rng) for k, s in strats.items()}
                try:
                    fn(**kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsified on example {i}/{n}: {kwargs!r}"
                    ) from e

        # NOT functools.wraps: pytest would resolve fixtures through the
        # __wrapped__ signature and demand the strategy args as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate

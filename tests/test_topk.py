"""Unit + property tests for the sparsification operators (paper Def. 1,
Lemma 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.topk import (
    block_topk,
    blocked_topk,
    blocked_view_shape,
    exact_topk,
    random_k,
)


def test_exact_topk_matches_numpy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=257).astype(np.float32))
    p = exact_topk(x, 17)
    top = np.argsort(-np.abs(np.asarray(x)))[:17]
    assert set(np.asarray(p.indices).tolist()) == set(top.tolist())
    np.testing.assert_allclose(np.asarray(p.densify())[top], np.asarray(x)[top])


@given(
    d=st.integers(3, 500),
    kfrac=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_exact_topk_is_delta_compressor(d, kfrac, seed):
    """Lemma 1: ||T_k(x) - x||^2 <= (1 - k/d) ||x||^2."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=d).astype(np.float32))
    k = max(1, int(kfrac * d))
    p = exact_topk(x, k)
    resid = float(jnp.sum((p.densify() - x) ** 2))
    bound = (1 - k / d) * float(jnp.sum(x**2)) + 1e-5
    assert resid <= bound


@given(
    d=st.integers(10, 2000),
    bs=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=30, deadline=None)
def test_block_topk_delta_compressor_and_indices(d, bs, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=d).astype(np.float32))
    k = max(1, d // 20)
    p = block_topk(x, k, block_size=bs)
    assert int(p.indices.max()) < d and int(p.indices.min()) >= 0
    resid = float(jnp.sum((p.densify() - x) ** 2))
    assert resid <= float(jnp.sum(x**2)) + 1e-5
    # block top-k selects at least k elements overall (per-block rounding up)
    nz = int(jnp.sum(p.densify() != 0))
    assert nz >= min(k, nz)


def test_random_k_unbiased():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=64).astype(np.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), 600)
    acc = jnp.zeros_like(x)
    for kk in keys:
        acc = acc + random_k(x, 8, kk).densify()
    est = acc / len(keys)
    np.testing.assert_allclose(np.asarray(est), np.asarray(x), atol=0.35)


@pytest.mark.parametrize(
    "shape,sharded_axis,axis_size",
    [
        ((64, 128), 1, 4),        # sharded last dim
        ((8, 32, 256), 1, 4),     # interior sharded
        ((100, 60), None, 1),     # unsharded
        ((7, 13), 0, 1),
    ],
)
def test_blocked_view_alignment(shape, sharded_axis, axis_size):
    blocked = blocked_view_shape(shape, sharded_axis, 64, axis_size)
    assert np.prod(blocked) == np.prod(shape)
    if sharded_axis is not None and sharded_axis == len(shape) - 1:
        # nbc must be a multiple of the axis size (shard-aligned blocks)
        assert blocked[-2] % axis_size == 0


@given(
    rows=st.integers(1, 8),
    bc=st.sampled_from([8, 32, 128]),
    kb=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=30, deadline=None)
def test_blocked_topk_matches_lax_topk(rows, bc, kb, seed):
    """The iterative masked-argmax selection == lax.top_k per block."""
    kb = min(kb, bc)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, 4, bc)).astype(np.float32))
    p = blocked_topk(x, kb)
    ref_v, ref_i = jax.lax.top_k(jnp.abs(x), kb)
    got_v = np.sort(np.abs(np.asarray(p.values)), axis=-1)
    exp_v = np.sort(np.asarray(ref_v), axis=-1)
    np.testing.assert_allclose(got_v, exp_v, rtol=1e-6, atol=1e-6)
    # densify puts selected values back in place
    dense = np.asarray(p.densify().reshape(x.shape))
    mask = dense != 0
    np.testing.assert_allclose(dense[mask], np.asarray(x)[mask], rtol=1e-6)

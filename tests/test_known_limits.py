"""Pinned reproductions of the XLA SPMD partitioner limits this framework
designs around (DESIGN.md §8). If these start PASSING after a jaxlib upgrade,
the workarounds (TP-only hierarchical FSDP, rotate-half RoPE, iterative
argmax selection) can be revisited.

Each repro runs in a SUBPROCESS because the failure mode is a fatal CHECK
(process abort), not a Python exception.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_PREFIX = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro import compat  # installs jax.shard_map/axis_size shims on older JAX
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = compat.make_mesh((2,2,2), ("pod","data","model"))
"""


def _run(body: str) -> bool:
    """Returns True if the snippet compiles (exit 0)."""
    p = subprocess.run(
        [sys.executable, "-c", _PREFIX + textwrap.dedent(body)],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    return p.returncode == 0 and "COMPILE_OK" in p.stdout


@pytest.mark.slow
def test_topk_sort_gathers_sharded_operand():
    """lax.top_k (sort) all-gathers a sharded operand even when the sort dim
    is local — why blocked_topk uses iterative masked argmax."""
    ok = _run("""
    import re
    x = jax.ShapeDtypeStruct((64, 16, 896), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "model", None)))
    @jax.jit
    def f(x):
        v, i = jax.lax.top_k(jnp.abs(x), 4)
        return v.sum() + i.sum()
    txt = f.lower(x).compile().as_text()
    big = [l for l in txt.splitlines()
           if re.search(r'all-gather\\(', l) and "f32[64,16,896]" in l]
    assert not big, "sort gathered the full operand"
    print("COMPILE_OK")
    """)
    assert not ok, (
        "lax.top_k now partitions sharded batch dims locally — the iterative "
        "argmax workaround in repro.core.topk.blocked_topk can be retired"
    )


@pytest.mark.slow
def test_fsdp_inside_manual_podaxis_shardmap_crashes():
    """Params FSDP-sharded over 'data' inside a manual-'pod' shard_map hits
    spmd_partitioner_util.cc CHECK — why hierarchical SASG is TP-only."""
    ok = _run("""
    from repro.configs import get_config
    from repro.models import build
    from repro.core import sasg_config
    from repro.dist.strategy import Strategy
    from repro.train import build_train_step
    from repro.optim import constant
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    strat = Strategy("hierarchical", ("pod",), ("pod","data"), "data", "data", "model", 2)
    built = build_train_step(model, sasg_config(k_ratio=0.05, max_delay=5), mesh, strat, constant(0.05))
    state = built.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 64), jnp.int32), "labels": jnp.zeros((8, 64), jnp.int32)}
    jax.jit(built.step).lower(state, batch).compile()
    print("COMPILE_OK")
    """)
    assert not ok, (
        "FSDP-over-data now composes with manual-pod shard_map — re-enable "
        "fsdp_axis='data' in dist/strategy.py hierarchical mode"
    )


@pytest.mark.slow
def test_workarounds_compile():
    """The shipped configuration (TP-only hierarchical) does compile."""
    ok = _run("""
    from repro.configs import get_config
    from repro.models import build
    from repro.core import sasg_config
    from repro.dist.strategy import choose_strategy
    from repro.train import build_train_step
    from repro.optim import constant
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    strat = choose_strategy(mesh, sasg_enabled=True)
    built = build_train_step(model, sasg_config(k_ratio=0.05, max_delay=5), mesh, strat, constant(0.05))
    state = built.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 64), jnp.int32), "labels": jnp.zeros((8, 64), jnp.int32)}
    jax.jit(built.step).lower(state, batch).compile()
    print("COMPILE_OK")
    """)
    assert ok

"""Overlapped exchange == synchronous exchange, bit for bit.

``Transport.exchange_overlapped`` dispatches each payload bucket to its
worker collective independently (so XLA can launch a bucket's all-gather as
soon as its gradient is ready, overlapping the remaining backward compute)
and commits the error-feedback state double-buffered AFTER the collectives.
The whole point of that restructuring is that it changes the SCHEDULE, not
the VALUES: per bucket it emits exactly the ops the synchronous
select-whole-tree-then-exchange path emits, so the update, the committed
payload cache, and the committed EF state must be bit-identical — across
the kernel/reference top-k sparse layouts and the dense qsgd path, for any
per-worker send/skip pattern (hypothesis-driven).

The end-to-end version of this property (full pipelined train step with
``overlap=True`` vs the sync step) runs inside the shared
``flat_pipe_check`` fixture's overlap leg.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

import repro.compat
from repro.comm import build_transport
from repro.core.compressors import CompressorConfig
from repro.core.types import tree_where

_COMPRESSORS = {
    "topk_kernel": CompressorConfig(name="topk_ef", k_ratio=0.1,
                                    block_size=32, topk_impl="kernel"),
    "topk_reference": CompressorConfig(name="topk_ef", k_ratio=0.1,
                                       block_size=32, topk_impl="reference"),
    "topk_flat_global": CompressorConfig(name="topk_ef", k_ratio=0.1,
                                         bucket="global", topk_impl="exact"),
    "qsgd": CompressorConfig(name="qsgd"),
}

_M = 2


def _both_paths(transport, g_prev, g, send, always_send):
    """Run the sync and overlapped exchange on one worker's (already
    device-local) gradients; returns worker-stacked outputs for shard_map."""
    key = jax.random.PRNGKey(7)
    e0 = transport.init_state(g)
    # a real stale cache: the payload of the PREVIOUS step's gradients
    stale, e1 = transport.encode(e0, g_prev, key)
    fresh, cand = transport.encode(e1, g, key)
    sb = None if always_send else send

    # synchronous reference: whole-tree select -> commit -> exchange
    payload_s = fresh if sb is None else tree_where(sb, fresh, stale)
    state_s = cand if sb is None else tree_where(sb, cand, e1)
    upd_s = transport.densify(transport.exchange(payload_s), g)

    upd_o, payload_o, state_o = transport.exchange_overlapped(
        fresh, stale, cand, e1, sb, g
    )
    out = (upd_s, upd_o, state_s, state_o, payload_s, payload_o)
    return jax.tree.map(lambda x: x[None], out)


@given(
    comp=st.sampled_from(sorted(_COMPRESSORS)),
    seed=st.integers(0, 2**16),
    sends=st.tuples(st.booleans(), st.booleans()),
    always_send=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_overlapped_exchange_bit_identical(comp, seed, sends, always_send):
    cfg = _COMPRESSORS[comp]
    transport = build_transport(cfg, ("data",), _M)
    rng = np.random.default_rng(seed)

    def mk():
        return {
            "w": jnp.asarray(rng.normal(size=(_M, 6, 32)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(_M, 21)).astype(np.float32)),
        }

    g_prev, g = mk(), mk()
    send = jnp.asarray(list(sends))
    mesh = repro.compat.make_mesh((_M,), ("data",))

    def worker(g_prev, g, send):
        strip = lambda t: jax.tree.map(lambda x: x[0], t)
        return _both_paths(
            transport, strip(g_prev), strip(g), send[0], always_send
        )

    sm = jax.shard_map(
        worker, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=P("data"),
        axis_names={"data"}, check_vma=False,
    )
    upd_s, upd_o, state_s, state_o, payload_s, payload_o = jax.jit(sm)(
        g_prev, g, send
    )

    for name, a, b in (
        ("update", upd_s, upd_o),
        ("ef_state", state_s, state_o),
        ("payload", payload_s, payload_o),
    ):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"{comp}: overlapped {name} diverged from sync",
            )

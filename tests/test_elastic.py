"""Elastic worker membership (train.elastic): in-run 4->2->4 resize is
bit-identical to restart-from-checkpoint elasticity on the same schedule,
state remapping carries/reinitializes exactly per DESIGN.md §5, recovery
templates use the caller's init key, and the replayable data stream yields
batch t identically across any resize/restore history."""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import PRESETS
from repro.core.error_feedback import worker_dims_match
from repro.data import (
    ReplayableStream,
    batch_fingerprint,
    indexed_classification_stream,
)
from repro.data.synthetic import synthetic_classification
from repro.models import build
from repro.optim import constant
from repro.train import (
    ElasticTrainer,
    FaultPlan,
    Trainer,
    TrainerConfig,
    WorkerMembership,
)
from repro.train.elastic import fresh_worker_state, remap_state

TOTAL, EVERY = 12, 4
SEED_DATA, SEED_INIT = 3, 7


def _pdiff(sa, sb):
    return max(
        float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params))
    )


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("fc_mnist")
    model = build(cfg)
    scfg = PRESETS["sasg"](k_ratio=0.1)
    xs, ys = synthetic_classification(256, cfg.vocab_size, (28, 28, 1), seed=0)
    mem = WorkerMembership(model, scfg, constant(0.05), sasg_enabled=True)

    def data():
        return indexed_classification_stream(xs, ys, batch=8, seed=SEED_DATA)

    return mem, data


@pytest.fixture(scope="module")
def clean_run(setup, tmp_path_factory):
    mem, data = setup
    built = mem.build(4)
    tc = TrainerConfig(
        total_steps=TOTAL, ckpt_dir=str(tmp_path_factory.mktemp("clean")),
        ckpt_every=EVERY, log_every=10**9, record_batches=True,
    )
    tr = Trainer(built, data(), tc, log_fn=lambda s: None)
    state = tr.run(init_key=jax.random.PRNGKey(SEED_INIT))
    return state, tr.batch_log


# -- replayable stream ----------------------------------------------------


def test_replayable_stream_is_pure_and_seekable():
    s = indexed_classification_stream(
        np.random.default_rng(0).normal(size=(32, 4)).astype(np.float32),
        np.zeros(32, np.int32), batch=4, seed=11,
    )
    first = [batch_fingerprint(next(s)) for _ in range(5)]
    s.seek(0)
    assert [batch_fingerprint(next(s)) for _ in range(5)] == first
    assert batch_fingerprint(s.batch_at(3)) == first[3]
    assert s.cursor == 5  # batch_at never moves the cursor
    with pytest.raises(ValueError):
        s.seek(-1)


def test_replayable_stream_batch_fn_contract():
    s = ReplayableStream(lambda t: {"x": np.full(2, t, np.float32)})
    assert next(s)["x"][0] == 0 and next(s)["x"][0] == 1
    s.seek(10)
    assert next(s)["x"][0] == 10


# -- state remapping ------------------------------------------------------


def test_remap_same_membership_is_bitexact(setup):
    mem, _ = setup
    built = mem.build(4)
    state = built.init(jax.random.PRNGKey(0))
    out = remap_state(state, built, built.strategy)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_remap_resize_carries_params_reinits_worker_state(setup):
    mem, _ = setup
    b4, b2 = mem.build(4), mem.build(2)
    state = b4.init(jax.random.PRNGKey(0))
    out = remap_state(state, b2, b4.strategy)
    # params / opt / gstate / counters / rng carried bit-exactly
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(out.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(state.rng), np.asarray(out.rng))
    # wstate re-stacked to the new worker count, re-initialized from the
    # carried params (stale_params == params on every worker row)
    assert worker_dims_match(out.wstate, 2)
    assert not worker_dims_match(out.wstate, 4)
    fresh = fresh_worker_state(b2, out.params)
    for a, b in zip(jax.tree.leaves(out.wstate), jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_membership_property_drives_the_carry_decision(setup):
    mem, _ = setup
    b4, b2 = mem.build(4), mem.build(2)
    assert b4.strategy.membership != b2.strategy.membership
    assert b4.strategy.membership == mem.build(4).strategy.membership


# -- the acceptance test: in-run resize == restart elasticity -------------


def test_inrun_resize_4_2_4_matches_restart_elasticity(setup, clean_run, tmp_path):
    mem, data = setup
    clean_state, clean_log = clean_run

    # Leg A: one ElasticTrainer, membership events at the checkpoint steps
    plan = FaultPlan().worker_drop(EVERY, to=2).worker_join(2 * EVERY, to=4)
    tc = TrainerConfig(
        total_steps=TOTAL, ckpt_dir=str(tmp_path / "inrun"),
        ckpt_every=EVERY, log_every=10**9, record_batches=True,
    )
    tr_a = ElasticTrainer(
        mem.build(4), data(), tc, membership=mem, plan=plan,
        log_fn=lambda s: None,
    )
    state_a = tr_a.run(init_key=jax.random.PRNGKey(SEED_INIT))
    assert [e["kind"] for e in tr_a.events] == ["resize", "resize"]
    assert tr_a.built.strategy.num_workers == 4

    # Leg B: restart-from-checkpoint elasticity — three Trainer processes
    # sharing one checkpoint dir, each phase on its own worker count
    ck = str(tmp_path / "restart")
    state_b = None
    for workers, upto in ((4, EVERY), (2, 2 * EVERY), (4, TOTAL)):
        tcb = TrainerConfig(
            total_steps=upto, ckpt_dir=ck, ckpt_every=EVERY,
            log_every=10**9, record_batches=True,
        )
        tr_b = Trainer(mem.build(workers), data(), tcb, log_fn=lambda s: None)
        state_b = tr_b.run(init_key=jax.random.PRNGKey(SEED_INIT))

    # bit-identical final parameters across the two elasticity mechanisms
    assert _pdiff(state_a, state_b) == 0.0

    # zero skipped / duplicated batches: every step consumed exactly once,
    # and each batch is the one the uninterrupted run consumed at that step
    assert [s for s, _ in tr_a.batch_log] == list(range(TOTAL))
    assert tr_a.batch_log == clean_log

    # a resize changes the update history (worker set changed), so leg A is
    # NOT bit-identical to the uninterrupted run — only to leg B
    assert _pdiff(state_a, clean_state) > 0.0


# -- recovery template uses the caller's init key -------------------------


def test_recovery_reinit_uses_caller_init_key(setup, clean_run):
    """No checkpoint dir: recovery falls back to a fresh start. The restore
    template must be built from the caller's init_key — with the old
    PRNGKey(0) template the recovered run silently diverges from its own
    initialization (and from the clean run)."""
    mem, data = setup
    clean_state, _ = clean_run
    plan = FaultPlan().crash(2)
    tc = TrainerConfig(total_steps=TOTAL, ckpt_dir=None, log_every=10**9)
    tr = ElasticTrainer(
        mem.build(4), data(), tc, membership=mem, plan=plan,
        log_fn=lambda s: None,
    )
    state = tr.run(init_key=jax.random.PRNGKey(SEED_INIT))
    assert [e["kind"] for e in tr.events] == ["crash", "recovery"]
    assert tr.events[-1]["restored_step"] == 0  # fresh start, no checkpoint
    assert _pdiff(state, clean_state) == 0.0

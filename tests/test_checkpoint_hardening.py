"""Checkpoint failure contract + data-pipeline hardening.

Covers the robustness satellites: save-failure propagation (the writer
thread must never die silently), bounded retry with backoff, newest-first
candidate fallback, verify catching truncated npy payloads, GC vs an
in-flight async save, strict_worker_dim restore, and the ShardedLoader
poisoned-sentinel / close() contract."""
import json
import os
import threading

import numpy as np
import pytest

from repro.data import ShardedLoader
from repro.train import checkpoint as CKPT
from repro.train.faults import corrupt_checkpoint


def _tree(v=0.0):
    return {
        "a": np.full((64, 3), v, np.float32),
        "b": {"c": np.arange(6, dtype=np.int32)},
    }


# -- save failure propagation ---------------------------------------------


def test_blocking_save_failure_raises(tmp_path):
    with pytest.raises(CKPT.CheckpointSaveError) as ei:
        CKPT.save(_tree(), str(tmp_path), 1, blocking=True,
                  retries=1, backoff=0.0, fail_attempts=5)
    assert ei.value.step == 1
    # no half-written checkpoint left behind
    assert CKPT.candidate_steps(str(tmp_path)) == []


def test_async_save_failure_raises_on_join(tmp_path):
    handle = CKPT.save(_tree(), str(tmp_path), 2, blocking=False,
                       retries=0, backoff=0.0, fail_attempts=3)
    with pytest.raises(CKPT.CheckpointSaveError):
        handle.join()


def test_save_retries_through_transient_failures(tmp_path):
    # fail_attempts <= retries: the backoff loop eats the failures
    h = CKPT.save(_tree(1.5), str(tmp_path), 3, blocking=True,
                  retries=2, backoff=0.0, fail_attempts=2)
    assert h.error is None
    assert CKPT.verify(str(tmp_path), 3)
    got = CKPT.restore(_tree(), str(tmp_path), 3)
    np.testing.assert_array_equal(np.asarray(got["a"]), _tree(1.5)["a"])


def test_save_records_meta(tmp_path):
    CKPT.save(_tree(), str(tmp_path), 4, meta={"num_workers": 4})
    assert CKPT.manifest_meta(str(tmp_path), 4) == {"num_workers": 4}
    assert CKPT.manifest_meta(str(tmp_path), 999) == {}


# -- candidate ordering + verification fallback ---------------------------


def test_candidate_steps_newest_first_skips_debris(tmp_path):
    for s in (1, 5, 3):
        CKPT.save(_tree(float(s)), str(tmp_path), s)
    os.makedirs(tmp_path / "step_7.tmp")          # in-flight write
    os.makedirs(tmp_path / "step_9")              # manifest-less debris
    assert CKPT.candidate_steps(str(tmp_path)) == [5, 3, 1]
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_verify_catches_truncated_leaf(tmp_path):
    CKPT.save(_tree(2.0), str(tmp_path), 1)
    assert CKPT.verify(str(tmp_path), 1)
    leaf = tmp_path / "step_1" / "00000.npy"
    with open(leaf, "r+b") as f:
        f.truncate(16)  # np.load raises ValueError on the mangled header
    assert not CKPT.verify(str(tmp_path), 1)


def test_corrupt_checkpoint_fails_verify_only_the_victim(tmp_path):
    CKPT.save(_tree(1.0), str(tmp_path), 1)
    CKPT.save(_tree(2.0), str(tmp_path), 2)
    victim = corrupt_checkpoint(str(tmp_path))  # newest
    assert victim == 2
    assert not CKPT.verify(str(tmp_path), 2)
    assert CKPT.verify(str(tmp_path), 1)


# -- GC vs in-flight async save -------------------------------------------


def test_gc_never_touches_inflight_tmp(tmp_path):
    for s in range(1, 6):
        CKPT.save(_tree(float(s)), str(tmp_path), s)
    os.makedirs(tmp_path / "step_6.tmp")  # pending atomic rename
    CKPT.gc_old(str(tmp_path), keep=2)
    assert CKPT.candidate_steps(str(tmp_path)) == [5, 4]
    assert (tmp_path / "step_6.tmp").is_dir()
    # the rename landing after GC yields a normal, newest candidate
    os.rename(tmp_path / "step_6.tmp", tmp_path / "step_6")
    with open(tmp_path / "step_6" / "manifest.json", "w") as f:
        json.dump({"step": 6, "meta": {}, "leaves": []}, f)
    assert CKPT.latest_step(str(tmp_path)) == 6


def test_gc_racing_async_save_keeps_result_consistent(tmp_path):
    # run GC concurrently with async saves; every surviving candidate must
    # still verify (no torn directories)
    handles = [
        CKPT.save(_tree(float(s)), str(tmp_path), s, blocking=False)
        for s in range(1, 7)
    ]
    t = threading.Thread(
        target=lambda: [CKPT.gc_old(str(tmp_path), keep=2) for _ in range(20)]
    )
    t.start()
    for h in handles:
        h.join()
    t.join()
    CKPT.gc_old(str(tmp_path), keep=2)
    survivors = CKPT.candidate_steps(str(tmp_path))
    assert len(survivors) == 2
    assert all(CKPT.verify(str(tmp_path), s) for s in survivors)


# -- strict_worker_dim ----------------------------------------------------


def test_restore_strict_worker_dim_on_worker_count_change(tmp_path):
    saved = {"wstate": np.arange(12, dtype=np.float32).reshape(4, 3)}
    CKPT.save(saved, str(tmp_path), 1)
    template = {"wstate": np.zeros((2, 3), np.float32)}  # 4 -> 2 workers
    with pytest.raises(ValueError, match="shape mismatch"):
        CKPT.restore(template, str(tmp_path), 1, strict_worker_dim=True)
    # non-strict: elastic fallback to the template leaf
    got = CKPT.restore(template, str(tmp_path), 1)
    np.testing.assert_array_equal(np.asarray(got["wstate"]), template["wstate"])


# -- ShardedLoader failure contract ---------------------------------------


def test_loader_propagates_worker_exception():
    def source():
        yield {"x": np.zeros(2)}
        yield {"x": np.ones(2)}
        raise ValueError("disk died mid-epoch")

    loader = ShardedLoader(source(), shardings=None, prefetch=2)
    next(loader), next(loader)
    with pytest.raises(ValueError, match="disk died mid-epoch"):
        next(loader)
    loader.close()


def test_loader_raises_stopiteration_on_exhaustion():
    loader = ShardedLoader(
        iter([{"x": np.zeros(2)}] * 3), shardings=None, prefetch=2
    )
    assert len(list(loader)) == 3  # no hang, clean StopIteration
    loader.close()


def test_loader_close_joins_prefetch_thread():
    def infinite():
        while True:
            yield {"x": np.zeros((1024,))}

    loader = ShardedLoader(infinite(), shardings=None, prefetch=1)
    next(loader)
    loader.close()
    assert not loader._thread.is_alive()


def test_loader_context_manager():
    with ShardedLoader(iter([{"x": np.zeros(2)}]), shardings=None) as loader:
        next(loader)
    assert not loader._thread.is_alive()

"""Tests for the beyond-paper optimizations (EXPERIMENTS.md §Perf iters 4-5)
and the loop-aware HLO cost analyzer (iter 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import CompressorConfig, SASGConfig, SelectionConfig
from repro.core.compressors import build_compressor


def test_compact_indices_roundtrip_and_wire_bits():
    cfg = CompressorConfig(
        name="topk_ef", k_ratio=0.1, block_size=64, topk_impl="sharded",
        wire_dtype="bfloat16", compact_indices=True,
    )
    comp = build_compressor(cfg)
    tree = {"w": jnp.zeros((8, 128))}
    state = comp.init(tree)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))}
    payload, state = comp.compress(state, g, jax.random.PRNGKey(0))
    p = payload["w"]
    assert p.indices.dtype == jnp.uint8          # block 64 fits in u8
    assert p.values.dtype == jnp.bfloat16
    dense = np.asarray(p.densify())
    # selected values round-trip through bf16 (~3 significant digits)
    mask = dense != 0
    np.testing.assert_allclose(
        dense[mask], np.asarray(g["w"])[mask], rtol=2e-2
    )
    # wire accounting (centralized in repro.comm.bits): 16-bit values +
    # 8-bit indices = 24 bits/element
    from repro.comm import account

    full_cfg = CompressorConfig(name="topk_ef", k_ratio=0.1, block_size=64,
                                topk_impl="sharded")
    assert account(cfg, tree).wire == pytest.approx(
        account(full_cfg, tree).wire * 24.0 / 64.0
    )
    # paper accounting unchanged (32 bits/coordinate convention)
    assert account(cfg, tree).paper == account(full_cfg, tree).paper


def test_probe_selection_converges(mesh2d):
    """SASG with rule (6) evaluated on a 25% probe still converges and still
    skips rounds."""
    from tests.test_sasg_core import _run

    cfg = SASGConfig(
        compressor=CompressorConfig(name="topk_ef", k_ratio=0.25, block_size=16),
        selection=SelectionConfig(enabled=True, max_delay=4, probe_fraction=0.25),
        name="sasg_probe",
    )
    _, loss, rounds = _run(cfg, mesh2d, T=80, distinct_batches=True)
    assert loss < 2e-2
    assert rounds <= 80 * 4


def test_probe_uses_fewer_grad_flops(mesh2d):
    """The probe variant's step HLO contains measurably fewer dot FLOPs than
    the full-batch rule (the auxiliary gradient shrinks)."""
    from repro.configs import get_config
    from repro.dist.strategy import Strategy
    from repro.launch import hlo_cost as HC
    from repro.models import build
    from repro.optim import constant
    from repro.train import build_train_step

    cfg = get_config("starcoder2_3b").reduced()
    model = build(cfg)
    strat = Strategy("flat", ("data",), ("data",), None, None, "model", 4)
    # per-worker batch of 8 rows so a 1/8 probe is a real reduction
    # (full rule: 8+8 row-passes; probe: 8+1+1 -> expect ~0.625x, exactly the
    # compute drop measured on the llama3 production cell in §Perf iter 4)
    batch = {"tokens": jnp.zeros((32, 64), jnp.int32),
             "labels": jnp.zeros((32, 64), jnp.int32)}

    def flops_for(probe):
        scfg = SASGConfig(
            compressor=CompressorConfig(name="topk_ef", k_ratio=0.05),
            selection=SelectionConfig(enabled=True, max_delay=4,
                                      probe_fraction=probe),
        )
        built = build_train_step(model, scfg, mesh2d, strat, constant(0.05))
        state = built.init(jax.random.PRNGKey(0))
        hlo = jax.jit(built.step).lower(state, batch).compile().as_text()
        return HC.analyze(hlo).flops

    full = flops_for(1.0)
    probed = flops_for(0.125)
    assert probed < 0.75 * full  # aux-grad share shrinks substantially


def test_hlo_cost_scan_scaling():
    """The loop-aware analyzer counts scan bodies x trip-count, exactly."""
    from repro.launch import hlo_cost as HC

    L, B, D = 8, 16, 32

    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), ()
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    w = jnp.zeros((L, D, D))
    x = jnp.zeros((B, D))
    hlo = jax.jit(f).lower(w, x).compile().as_text()
    cost = HC.analyze(hlo)
    expected = 2.0 * B * D * D * L
    assert cost.flops == pytest.approx(expected, rel=1e-6)


def test_hlo_cost_collective_scaling(mesh2d):
    """Collectives inside scan bodies scale by trip count."""
    from repro.launch import hlo_cost as HC

    L, B, D = 6, 8, 16

    def f(w, x):
        def body(h, wi):
            y = h @ wi
            y = jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh2d, P("data", None))
            )
            return jnp.tanh(y), ()
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    w = jax.ShapeDtypeStruct(
        (L, D, D), jnp.float32,
        sharding=NamedSharding(mesh2d, P(None, "model", None)),
    )
    x = jax.ShapeDtypeStruct(
        (B, D), jnp.float32, sharding=NamedSharding(mesh2d, P("data", None))
    )
    hlo = jax.jit(f).lower(w, x).compile().as_text()
    cost = HC.analyze(hlo)
    # contraction over the model-sharded dim forces a per-step all-reduce;
    # the analyzer must count it L times (allow fusion slack, require >=L/2)
    ar = cost.coll_wire.get("all-reduce", 0.0) + cost.coll_wire.get("reduce-scatter", 0.0)
    single = 2.0 * (2 - 1) / 2 * (B // 4) * D * 4  # ring factor * shard bytes
    assert ar >= single * L / 2


def test_sasg_opt_preset_via_dryrun_config():
    """The sasg_opt dryrun variant builds a valid config."""
    scfg = SASGConfig(
        compressor=CompressorConfig(name="topk_ef", k_ratio=0.01,
                                    wire_dtype="bfloat16", compact_indices=True),
        selection=SelectionConfig(enabled=True, max_delay=10, probe_fraction=0.125),
        name="sasg_opt",
    )
    comp = build_compressor(scfg.compressor)
    assert comp.kind == "sparse"
    assert scfg.selection.probe_fraction == 0.125

"""1F1B engine + compressed activation ring: schedule and wire-format suite.

Covers the PR-8 seams:

- ``resolve_microbatches`` no longer degrades silently: prime batch sizes
  and indivisible requests warn (``n_micro=1`` serializes the pipeline);
  ``requested <= 1`` is an explicit ask and stays silent;
- the 1F1B engine (``pipeline_vag_1f1b``) is bit-compatible with the GPipe
  reference engine under the identity activation layout, and matches the
  sequential model for compressed layouts' *structure* (runs, replicated
  loss, full grads);
- ``ActivationLayout``: identity encode/decode is the bitwise identity, the
  blocked top-k round trip preserves the selected support, and
  ``payload_bits`` agrees with the actual encoded wire arrays;
- legacy ``topk_impl`` spellings ("sharded"/"block") still resolve through
  ``CompressorConfig.resolved_layout/resolved_impl`` AND through the new
  default-1F1B pipelined train step (payload path for per-shard, dense
  fallback for per-tensor);
- the engine knob: unknown engines fail eagerly, ``pipeline_engine="gpipe"``
  still builds the reference schedule, and a compressed ``act_layout``
  shrinks the modeled ring bits ≥ 10x below the dense GPipe model.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.compat
from repro.comm.transport import ActivationLayout
from repro.configs import get_config
from repro.core import CompressorConfig, SASGConfig, SelectionConfig
from repro.core import metrics as CM
from repro.dist.pipeline import build_pipelined_vag, resolve_microbatches
from repro.dist.strategy import choose_strategy
from repro.models import build
from repro.models.model import PipelineDef
from repro.optim import constant
from repro.train import build_train_step


# ---------------------------------------------------------------------------
# resolve_microbatches: loud degradation (satellite 1)
# ---------------------------------------------------------------------------

def test_resolve_microbatches_warns_on_degrade():
    # prime batch size: nothing divides -> serializes to 1, loudly
    with pytest.warns(UserWarning, match="degrading to 1"):
        assert resolve_microbatches(7, 4) == 1
    with pytest.warns(UserWarning, match="degrading to 1"):
        assert resolve_microbatches(13, 8) == 1
    # divisible-but-smaller fallback warns too (still a perf change)
    with pytest.warns(UserWarning, match="degrading to 3"):
        assert resolve_microbatches(6, 4) == 3
    with pytest.warns(UserWarning, match="degrading to 6"):
        assert resolve_microbatches(12, 8) == 6


def test_resolve_microbatches_silent_cases():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        # exact divisors: no degradation, no warning
        assert resolve_microbatches(8, 4) == 4
        assert resolve_microbatches(12, 12) == 12
        # requested <= 0 clamps to 1 (explicit no-microbatching), silent
        assert resolve_microbatches(8, 0) == 1
        assert resolve_microbatches(7, 0) == 1
        assert resolve_microbatches(5, 1) == 1
        assert resolve_microbatches(1, 1) == 1


# ---------------------------------------------------------------------------
# engines: 1F1B == GPipe == sequential on a toy PipelineDef
# ---------------------------------------------------------------------------

def _layer_fn(w, h):
    return jnp.tanh(h @ w)


def _toy_pdef(n_layers):
    return PipelineDef(
        n_layers=n_layers,
        trunk_path=("trunk",),
        prepare=lambda params, batch: batch["x"] @ params["w_in"],
        layer_fn=_layer_fn,
        finish=lambda params, h, batch: jnp.mean(
            (h @ params["w_out"] - batch["y"]) ** 2
        ),
    )


def _toy_setup(n_layers=4, b=8, d_in=5, d=6, d_out=3, seed=2):
    rng = np.random.default_rng(seed)
    params = {
        "w_in": jnp.asarray(rng.normal(size=(d_in, d)).astype(np.float32) * 0.4),
        "trunk": jnp.asarray(
            rng.normal(size=(n_layers, d, d)).astype(np.float32) * 0.3
        ),
        "w_out": jnp.asarray(rng.normal(size=(d, d_out)).astype(np.float32) * 0.4),
    }
    batch = {
        "x": jnp.asarray(rng.normal(size=(b, d_in)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(b, d_out)).astype(np.float32)),
    }
    return params, batch


def _run_engine(S, engine, act_layout=None, n_layers=4):
    params, batch = _toy_setup(n_layers=n_layers)
    pdef = _toy_pdef(n_layers)
    vag = build_pipelined_vag(pdef, axis="stage", engine=engine,
                              act_layout=act_layout)
    mesh = repro.compat.make_mesh((S,), ("stage",))
    sm = jax.shard_map(
        vag, mesh=mesh,
        in_specs=({"w_in": P(), "trunk": P("stage"), "w_out": P()}, P()),
        out_specs=(P(), {"w_in": P(), "trunk": P(), "w_out": P()}),
        axis_names={"stage"}, check_vma=False,
    )
    loss, g = jax.jit(sm)(params, batch)

    def ref_loss(params_, batch_):
        h = pdef.prepare(params_, batch_)
        for l in range(n_layers):
            h = _layer_fn(params_["trunk"][l], h)
        return pdef.finish(params_, h, batch_)

    loss_r, g_r = jax.value_and_grad(ref_loss)(params, batch)
    return loss, g, loss_r, g_r


@pytest.mark.parametrize("S", [1, 2, 4])
def test_1f1b_matches_sequential(S):
    loss, g, loss_r, g_r = _run_engine(S, "1f1b")
    np.testing.assert_allclose(float(loss), float(loss_r), rtol=1e-6)
    for k in g_r:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_r[k]),
                                   rtol=1e-4, atol=1e-6, err_msg=k)


@pytest.mark.parametrize("S", [2, 4])
def test_1f1b_matches_gpipe_identity_layout(S):
    """With the identity layout the two engines compute the same microbatch
    forwards and the same output broadcast, so losses are bitwise equal and
    gradients agree to accumulation-order reassociation."""
    l1, g1, _, _ = _run_engine(S, "1f1b")
    l2, g2, _, _ = _run_engine(S, "gpipe")
    assert float(l1) == float(l2)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=0, atol=1e-7, err_msg=k)


def test_1f1b_compressed_layout_runs_and_is_stage_consistent():
    """A lossy wire layout still yields a replicated loss and full grads (all
    stages decode the SAME values); the loss sits near the exact one."""
    lay = ActivationLayout(wire_dtype="bfloat16", k_ratio=0.5, block_size=16)
    loss, g, loss_r, _ = _run_engine(2, "1f1b", act_layout=lay)
    assert np.isfinite(float(loss))
    # lossy but not garbage: same order of magnitude as the exact loss
    assert abs(float(loss) - float(loss_r)) < 0.5 * abs(float(loss_r)) + 0.1
    for k in g:
        assert np.all(np.isfinite(np.asarray(g[k])))


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown pipeline engine"):
        build_pipelined_vag(_toy_pdef(4), axis="stage", engine="interleaved2")


# ---------------------------------------------------------------------------
# ActivationLayout: wire format properties
# ---------------------------------------------------------------------------

def test_activation_layout_identity_roundtrip_bitwise():
    lay = ActivationLayout()
    assert lay.is_identity
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 5, 7))
                    .astype(np.float32))
    parts = lay.encode(x)
    assert len(parts) == 1 and parts[0].dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(lay.decode(parts, x.shape, x.dtype)), np.asarray(x)
    )


def test_activation_layout_topk_roundtrip_support():
    lay = ActivationLayout(wire_dtype="float32", k_ratio=0.25, block_size=8)
    assert not lay.is_identity
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    vals, idxs = lay.encode(x)
    assert idxs.dtype == jnp.uint8          # block-local indices, block <= 256
    dec = np.asarray(lay.decode((vals, idxs), x.shape, x.dtype))
    xf = np.asarray(x).reshape(-1)
    # decoded entries are either zero or exactly the original value
    nz = dec.reshape(-1) != 0
    np.testing.assert_array_equal(dec.reshape(-1)[nz], xf[nz])
    # per block of 8, exactly k=2 survivors, and they are the top-|.| ones
    blocks = xf.reshape(-1, 8)
    kept = nz.reshape(-1, 8)
    assert (kept.sum(axis=1) == 2).all()
    for bi in range(blocks.shape[0]):
        top2 = set(np.argsort(-np.abs(blocks[bi]))[:2])
        assert set(np.nonzero(kept[bi])[0]) <= set(range(8))
        assert set(np.nonzero(kept[bi])[0]) == top2 or np.isclose(
            np.abs(blocks[bi][sorted(top2)[-1]]),
            np.abs(blocks[bi][np.nonzero(kept[bi])[0]]).min(),
        )


def test_activation_layout_payload_bits_match_encode():
    """The analytic ``payload_bits`` (shared with PipelineCommModel and the
    HLO audit) equals the actual bit-width of the encoded wire arrays."""
    for lay, elems in (
        (ActivationLayout(), 1000),
        (ActivationLayout(wire_dtype="bfloat16"), 1000),
        (ActivationLayout(k_ratio=0.05, block_size=256), 32768),
        (ActivationLayout(wire_dtype="bfloat16", k_ratio=0.05,
                          block_size=256), 32768),
    ):
        x = jnp.ones((elems,), jnp.float32)
        parts = lay.encode(x)
        actual = sum(p.size * p.dtype.itemsize * 8 for p in parts)
        assert lay.payload_bits(elems) == actual, (lay, elems)


def test_compressed_ring_model_10x_below_dense():
    """The PR's acceptance shape: bf16 + 5% blocked top-k on the 1F1B ring
    models ≥ 10x fewer ring bits than the dense GPipe ring, same geometry."""
    S, n, act = 2, 2, 32768
    lay = ActivationLayout(wire_dtype="bfloat16", k_ratio=0.05, block_size=256)
    dense = CM.PipelineCommModel(stages=S, n_micro=n, act_elems=act)
    comp = CM.PipelineCommModel(
        stages=S, n_micro=n, act_elems=act, engine="1f1b",
        hop_payload_bits=lay.payload_bits(act),
        bcast_payload_bits=lay.payload_bits(n * act),
    )
    assert dense.ring_bits_per_step() / comp.ring_bits_per_step() >= 10.0


# ---------------------------------------------------------------------------
# legacy topk_impl spellings through the default-1F1B train step (satellite 2)
# ---------------------------------------------------------------------------

def _cnn_model(width=16):
    return build(dataclasses.replace(get_config("cnn_cifar"), d_model=width))


def _cnn_batches(n, b=8, seed=0):
    rng = np.random.default_rng(seed)
    return [{
        "x": jnp.asarray(rng.normal(size=(b, 32, 32, 3)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 10, size=(b,)).astype(np.int32)),
    } for _ in range(n)]


@pytest.mark.parametrize("spelling,layout,impl,payload_path", [
    ("sharded", "per_shard", "reference", True),
    ("block", "per_tensor", "reference", False),
])
def test_legacy_spellings_resolve_through_1f1b_step(spelling, layout, impl,
                                                    payload_path):
    """The pre-rename configs (topk_impl="sharded"/"block") must keep
    resolving — and keep BUILDING — through the new default-1F1B scheduler:
    "sharded" lands on the per-shard payload-gather hot path, "block" on the
    per-tensor dense fallback."""
    cfg = CompressorConfig(name="topk_ef", k_ratio=0.05, block_size=64,
                           topk_impl=spelling)
    assert cfg.resolved_layout() == layout
    assert cfg.resolved_impl() == impl

    model = _cnn_model()
    scfg = SASGConfig(compressor=cfg, selection=SelectionConfig(enabled=False),
                      name=f"legacy_{spelling}")
    assert scfg.pipeline_engine == "1f1b"   # the new default schedule
    mesh = repro.compat.make_mesh((2, 2), ("data", "stage"))
    s_pipe = choose_strategy(mesh, sasg_enabled=True, pipeline_stages=2,
                             trunk_layers=model.pipeline.n_layers)
    built = build_train_step(model, scfg, mesh, s_pipe, constant(0.05))
    assert built.exchange.transport.layout == layout
    assert (built.exchange.transport.stage is not None) == payload_path

    state = built.init(jax.random.PRNGKey(0))
    for batch in _cnn_batches(2):
        state, mets = built.jit_step(state, batch)
        assert np.isfinite(float(mets["loss"]))
        assert float(mets["pipe_ring_bits_step"]) > 0

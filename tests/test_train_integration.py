"""End-to-end train-step integration: build_train_step on flat and
hierarchical strategies, checkpoint/restore, fault recovery, elastic resize."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import sasg_config, sgd_config, sparse_config
from repro.data import token_stream
from repro.dist.strategy import Strategy, choose_strategy
from repro.models import build
from repro.optim import constant
from repro.train import TrainerConfig, Trainer, build_train_step, checkpoint as CKPT


def _built(mesh, strat, cfg_model="llama3_8b", algo=None):
    cfg = get_config(cfg_model).reduced()
    model = build(cfg)
    scfg = algo or sasg_config(k_ratio=0.05, max_delay=4)
    return cfg, build_train_step(model, scfg, mesh, strat, constant(0.05))


def test_flat_strategy_runs_and_skips(mesh2d):
    strat = Strategy("flat", ("data",), ("data",), None, None, "model", 4)
    cfg, built = _built(mesh2d, strat)
    state = built.init(jax.random.PRNGKey(0))
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)
    losses, sents = [], []
    for _ in range(6):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        state, mets = built.jit_step(state, batch)
        losses.append(float(mets["loss"]))
        sents.append(float(mets["num_sent"]))
    assert all(np.isfinite(losses))
    assert sents[0] == 4  # first step always uploads
    assert float(state.counters.rounds) == sum(sents)


def test_hierarchical_strategy_runs(mesh3d):
    strat = choose_strategy(mesh3d, sasg_enabled=True)
    assert strat.name == "hierarchical"
    cfg, built = _built(mesh3d, strat)
    state = built.init(jax.random.PRNGKey(0))
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)
    for _ in range(4):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        state, mets = built.jit_step(state, batch)
    assert np.isfinite(float(mets["loss"]))


def test_plain_strategy_fallback(mesh3d):
    strat = choose_strategy(mesh3d, sasg_enabled=True, params_bytes=10**14)
    assert strat.name == "plain"  # too big to worker-replicate
    cfg, built = _built(mesh3d, strat, algo=sgd_config())
    state = built.init(jax.random.PRNGKey(0))
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    state, mets = built.jit_step(state, batch)
    assert np.isfinite(float(mets["loss"]))


def test_checkpoint_roundtrip_and_resume(tmp_path, mesh2d):
    strat = Strategy("flat", ("data",), ("data",), None, None, "model", 4)
    cfg, built = _built(mesh2d, strat)
    state = built.init(jax.random.PRNGKey(0))
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    state, _ = built.jit_step(state, batch)
    CKPT.save(state, str(tmp_path), step=1)
    assert CKPT.latest_step(str(tmp_path)) == 1
    assert CKPT.verify(str(tmp_path), 1)
    template = built.init(jax.random.PRNGKey(1))
    restored = CKPT.restore(template, str(tmp_path), 1, shardings=built.state_shardings)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
    # restored state continues training identically
    s1, m1 = built.jit_step(state, batch)
    s2, m2 = built.jit_step(restored, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)


def test_trainer_recovers_from_injected_failure(tmp_path, mesh2d):
    strat = Strategy("flat", ("data",), ("data",), None, None, "model", 4)
    cfg, built = _built(mesh2d, strat)
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)

    def data():
        while True:
            yield {k: jnp.asarray(v) for k, v in next(stream).items()}

    fail_at = {5}

    def fault(step):
        if step in fail_at:
            fail_at.discard(step)  # fail once
            raise RuntimeError("injected node failure")

    tcfg = TrainerConfig(total_steps=8, ckpt_dir=str(tmp_path), ckpt_every=2,
                         log_every=100, ckpt_async=False)
    tr = Trainer(built, data(), tcfg, fault_hook=fault, log_fn=lambda s: None)
    state = tr.run(init_key=jax.random.PRNGKey(0))
    assert CKPT.latest_step(str(tmp_path)) == 8
    assert len(tr.history) >= 8


def test_elastic_restore_across_meshes(tmp_path, mesh2d, mesh3d):
    """A checkpoint from the 4-worker flat mesh restores onto the 2-pod
    hierarchical mesh: params carry over; SASG worker state re-initializes."""
    strat = Strategy("flat", ("data",), ("data",), None, None, "model", 4)
    cfg, built = _built(mesh2d, strat)
    state = built.init(jax.random.PRNGKey(0))
    CKPT.save(state, str(tmp_path), step=3)

    strat2 = choose_strategy(mesh3d, sasg_enabled=True)
    cfg2, built2 = _built(mesh3d, strat2)
    template = built2.init(jax.random.PRNGKey(9))
    restored = CKPT.restore(
        template, str(tmp_path), 3, shardings=built2.state_shardings
    )
    # params restored exactly despite the mesh change
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
    stream = token_stream(cfg2.vocab_size, 8, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    _, mets = built2.jit_step(restored, batch)
    assert np.isfinite(float(mets["loss"]))


def test_comm_counters_accounting(mesh2d):
    """bits totals follow the static per-upload costs exactly."""
    strat = Strategy("flat", ("data",), ("data",), None, None, "model", 4)
    cfg, built = _built(mesh2d, strat, algo=sparse_config(k_ratio=0.1))
    state = built.init(jax.random.PRNGKey(0))
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)
    T = 3
    for _ in range(T):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        state, mets = built.jit_step(state, batch)
    # sparse has no selection: every worker uploads every step
    assert float(state.counters.rounds) == T * 4
    np.testing.assert_allclose(
        float(state.counters.bits_paper), T * 4 * built.bits_paper, rtol=1e-6
    )
    assert float(state.counters.bits_wire) > float(state.counters.bits_paper)

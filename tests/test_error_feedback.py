"""Property tests on the EF invariants (paper Lemma 2 flavor)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.compressors import CompressorConfig, build_compressor
from repro.core.error_feedback import ef_apply, ef_init
from repro.core.topk import exact_topk


@given(
    d=st.integers(4, 300),
    steps=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_ef_conservation(d, steps, seed):
    """compressed + residual == corrected input, exactly, every step; so the
    telescoped sum of compressed outputs equals the sum of inputs minus the
    final residual (nothing is ever lost — paper §3.2 'eventually all the
    gradient information will be transmitted')."""
    rng = np.random.default_rng(seed)
    tree = {"w": jnp.zeros((d,))}
    state = ef_init(tree)
    total_in = np.zeros(d, np.float32)
    total_out = np.zeros(d, np.float32)
    for _ in range(steps):
        g = {"w": jnp.asarray(rng.normal(size=d).astype(np.float32))}
        total_in += np.asarray(g["w"])
        comp, state = ef_apply(state, g, lambda f: exact_topk(f, max(1, d // 10)).densify())
        total_out += np.asarray(comp["w"])
    resid = np.asarray(state.error["w"])
    np.testing.assert_allclose(total_out + resid, total_in, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 2**16), steps=st.integers(2, 10))
@settings(max_examples=15, deadline=None)
def test_topk_ef_compressor_conservation(seed, steps):
    """Same conservation through the production compressor (sharded impl)."""
    rng = np.random.default_rng(seed)
    cfg = CompressorConfig(name="topk_ef", k_ratio=0.1, block_size=16,
                           topk_impl="sharded")
    comp = build_compressor(cfg)
    tree = {"a": jnp.zeros((8, 32)), "b": jnp.zeros((50,))}
    state = comp.init(tree)
    tot_in = {k: np.zeros(v.shape, np.float32) for k, v in tree.items()}
    tot_out = {k: np.zeros(v.shape, np.float32) for k, v in tree.items()}
    key = jax.random.PRNGKey(0)
    for _ in range(steps):
        g = {k: jnp.asarray(rng.normal(size=v.shape).astype(np.float32))
             for k, v in tree.items()}
        payload, state = comp.compress(state, g, key)
        for k in tree:
            tot_in[k] += np.asarray(g[k])
            tot_out[k] += np.asarray(payload[k].densify()).reshape(tree[k].shape)
    for k in tree:
        resid = np.asarray(state[k])
        np.testing.assert_allclose(tot_out[k] + resid, tot_in[k], rtol=1e-4, atol=1e-4)


def test_error_bounded_under_repeated_compression():
    """Lemma 2: residuals do not blow up over many steps."""
    rng = np.random.default_rng(0)
    cfg = CompressorConfig(name="topk_ef", k_ratio=0.05, block_size=32,
                           topk_impl="sharded")
    comp = build_compressor(cfg)
    tree = {"w": jnp.zeros((16, 64))}
    state = comp.init(tree)
    key = jax.random.PRNGKey(0)
    norms = []
    for _ in range(200):
        g = {"w": jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))}
        _, state = comp.compress(state, g, key)
        norms.append(float(jnp.linalg.norm(state["w"])))
    # bounded: the tail of the sequence should not grow
    assert max(norms[100:]) < 3.0 * max(norms[:50]) + 1.0

"""Shared fixtures. NOTE: device count is NOT forced here (smoke tests and
benches must see the real 1-CPU environment; only dryrun.py forces 512) —
tests that need a mesh spawn fake devices in their own module via an
env-guarded subprocess or use the 8-device modules below."""
import importlib.util
import os
import sys

# tests that need multiple devices are grouped in files that set this flag
# BEFORE importing jax (pytest imports conftest first, so set it here for the
# whole test session: 8 fake devices is small enough not to distort smoke
# perf, and lets sharding/integration tests build meshes).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import repro.compat  # noqa: E402,F401  (installs jax.shard_map/axis_size shims on older JAX)

import pytest  # noqa: E402

# hypothesis fallback: the test image may not ship hypothesis (and cannot
# install it); load the deterministic stub so the property-test modules
# still collect and run. The real package always wins when present.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (multi-minute known-limits XLA compiles)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow known-limits compile; pass --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(scope="session")
def mesh2d():
    # compat.make_mesh guards the AxisType import: older JAX builds the mesh
    # without axis_types, newer JAX gets Auto axes.
    return repro.compat.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh3d():
    return repro.compat.make_mesh((2, 2, 2), ("pod", "data", "model"))

"""Shared fixtures. NOTE: device count is NOT forced here (smoke tests and
benches must see the real 1-CPU environment; only dryrun.py forces 512) —
tests that need a mesh spawn fake devices in their own module via an
env-guarded subprocess or use the 8-device modules below."""
import os
import sys

# tests that need multiple devices are grouped in files that set this flag
# BEFORE importing jax (pytest imports conftest first, so set it here for the
# whole test session: 8 fake devices is small enough not to distort smoke
# perf, and lets sharding/integration tests build meshes).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh2d():
    from jax.sharding import AxisType

    return jax.make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh3d():
    from jax.sharding import AxisType

    return jax.make_mesh(
        (2, 2, 2), ("pod", "data", "model"), axis_types=(AxisType.Auto,) * 3
    )

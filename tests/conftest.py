"""Shared fixtures. NOTE: device count is NOT forced here (smoke tests and
benches must see the real 1-CPU environment; only dryrun.py forces 512) —
tests that need a mesh spawn fake devices in their own module via an
env-guarded subprocess or use the 8-device modules below."""
import importlib.util
import os
import sys

# tests that need multiple devices are grouped in files that set this flag
# BEFORE importing jax (pytest imports conftest first, so set it here for the
# whole test session: 8 fake devices is small enough not to distort smoke
# perf, and lets sharding/integration tests build meshes).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import repro.compat  # noqa: E402,F401  (installs jax.shard_map/axis_size shims on older JAX)

import pytest  # noqa: E402

# hypothesis fallback: the test image may not ship hypothesis (and cannot
# install it); load the deterministic stub so the property-test modules
# still collect and run. The real package always wins when present.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

# bounded hypothesis profiles: CI runs the property suites with a fixed,
# smaller example budget (HYPOTHESIS_PROFILE=ci in .github/workflows/ci.yml).
# hasattr-guarded: the deterministic stub above has no profile machinery and
# simply runs each test's own max_examples.
from hypothesis import settings as _hyp_settings  # noqa: E402

if hasattr(_hyp_settings, "register_profile"):
    _hyp_settings.register_profile("ci", max_examples=20, deadline=None)
    _hyp_settings.register_profile("dev", deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (multi-minute known-limits XLA compiles)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow known-limits compile; pass --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


def max_param_diff(sa, sb):
    """Host-side max-abs param difference between two TrainStates (the two
    states may live on different (sub)meshes, so compare as numpy)."""
    import numpy as np

    return max(
        float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params))
    )


@pytest.fixture(scope="session")
def flat_pipe_check():
    """Shared flat-vs-pipelined equality harness (the acceptance check of the
    pipeline x SASG composition, promoted from tests/test_pipeline_sasg.py so
    the stage-sharded-EF suite reuses it verbatim).

    Builds the flat and pipelined train steps for the same (model, config),
    asserts the static bit counters and initial states are identical, runs
    every batch through both, and asserts per step: identical send/skip
    decisions, losses within ``loss_rtol``, params within ``param_tol``
    (fp32-reassociation / top-k tie-flip tiers — test_pipeline_sasg module
    docstring), and that only the pipelined run surfaces the stage-axis
    traffic split (pipe_bits_step == pipe_ring_bits_step +
    pipe_gather_bits_step). Finishes by asserting the cumulative rounds/bits
    counters agree. Returns the built steps, final states, and the per-step
    send history for test-specific follow-up asserts.

    ``overlap_leg=True`` (the default) additionally builds the SAME
    pipelined config with ``overlap=True`` — the per-bucket dispatch +
    double-buffered EF commit (``Transport.exchange_overlapped``) — and
    asserts it is BIT-IDENTICAL to the synchronous pipelined run every step
    (params, sends, losses): overlapping the exchange with backward compute
    must not move a single bit of error-feedback state.
    """
    import dataclasses as _dc

    import numpy as np

    from repro.dist.strategy import choose_strategy
    from repro.optim import constant
    from repro.train import build_train_step

    def run(model, scfg, mesh_flat, mesh_pipe, stages, batches, lr=0.05,
            param_tol=2e-2, loss_rtol=1e-2, overlap_leg=True):
        s_flat = choose_strategy(mesh_flat, sasg_enabled=True)
        s_pipe = choose_strategy(
            mesh_pipe, sasg_enabled=True, pipeline_stages=stages,
            trunk_layers=model.pipeline.n_layers,
        )
        assert s_pipe.pipelined and s_pipe.pipeline_stages == stages
        bf = build_train_step(model, scfg, mesh_flat, s_flat, constant(lr))
        bp = build_train_step(model, scfg, mesh_pipe, s_pipe, constant(lr))
        assert bf.bits_wire == bp.bits_wire and bf.bits_paper == bp.bits_paper
        sf, sp = bf.init(jax.random.PRNGKey(0)), bp.init(jax.random.PRNGKey(0))
        assert max_param_diff(sf, sp) == 0.0
        bo = so = None
        if overlap_leg:
            bo = build_train_step(model, _dc.replace(scfg, overlap=True),
                                  mesh_pipe, s_pipe, constant(lr))
            assert bo.bits_wire == bp.bits_wire
            so = bo.init(jax.random.PRNGKey(0))
        sents = []
        for batch in batches:
            sf, mf = bf.jit_step(sf, batch)
            sp, mp = bp.jit_step(sp, batch)
            assert float(mf["num_sent"]) == float(mp["num_sent"])
            sents.append(float(mp["num_sent"]))
            np.testing.assert_allclose(float(mf["loss"]), float(mp["loss"]),
                                       rtol=loss_rtol)
            assert max_param_diff(sf, sp) < param_tol
            if overlap_leg:
                so, mo = bo.jit_step(so, batch)
                assert float(mo["num_sent"]) == float(mp["num_sent"])
                assert float(mo["loss"]) == float(mp["loss"])
                assert max_param_diff(so, sp) == 0.0
            # only pipelined runs surface the stage-axis traffic, split into
            # the activation ring and the gradient payload gather
            assert "pipe_bits_step" not in mf
            assert float(mp["pipe_ring_bits_step"]) > 0
            assert float(mp["pipe_bits_step"]) == pytest.approx(
                float(mp["pipe_ring_bits_step"])
                + float(mp["pipe_gather_bits_step"])
            )
        if overlap_leg:
            # the double-buffered EF commit leaves the FULL worker state —
            # error buffers, stale payload cache, taus — bit-identical
            for a, b in zip(jax.tree.leaves(so.wstate),
                            jax.tree.leaves(sp.wstate)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(sf.counters.rounds) == float(sp.counters.rounds)
        np.testing.assert_allclose(float(sf.counters.bits_wire),
                                   float(sp.counters.bits_wire), rtol=1e-6)
        np.testing.assert_allclose(float(sf.counters.bits_paper),
                                   float(sp.counters.bits_paper), rtol=1e-6)
        return {"bf": bf, "bp": bp, "sf": sf, "sp": sp, "sents": sents,
                "bo": bo, "so": so}

    return run


@pytest.fixture(scope="session")
def mesh2d():
    # compat.make_mesh guards the AxisType import: older JAX builds the mesh
    # without axis_types, newer JAX gets Auto axes.
    return repro.compat.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh3d():
    return repro.compat.make_mesh((2, 2, 2), ("pod", "data", "model"))

"""Optimizers, schedules, comm metrics model, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import CommModel, LinkModel
from repro.data import ShardedLoader, synthetic_classification, token_stream
from repro.optim import (
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    constant,
    cosine_decay,
    momentum,
    sgd,
    step_decay,
    warmup_cosine,
)


def _quad_problem():
    A = jnp.asarray(np.diag([1.0, 5.0, 10.0]).astype(np.float32))

    def loss(p):
        return 0.5 * p @ A @ p

    return loss, jnp.asarray([1.0, 1.0, 1.0])


def test_sgd_momentum_adam_converge():
    loss, p0 = _quad_problem()
    for opt in [sgd(0.05), momentum(0.05, 0.9), adamw(0.3)]:
        p, st = p0, opt.init(p0)
        for _ in range(200):
            g = jax.grad(loss)(p)
            upd, st = opt.update(g, st, p)
            p = apply_updates(p, upd)
        assert float(loss(p)) < 1e-3


def test_clip_and_chain():
    loss, p0 = _quad_problem()
    opt = chain(clip_by_global_norm(1.0), sgd(0.1))
    st = opt.init(p0)
    g = jax.tree.map(lambda x: x * 1e6, jax.grad(loss)(p0))
    upd, st = opt.update(g, st, p0)
    gn = float(jnp.linalg.norm(upd))
    assert gn <= 0.1 + 1e-5


def test_schedules():
    assert float(constant(0.1)(jnp.asarray(0))) == np.float32(0.1)
    sd = step_decay(0.1, [10, 20])
    assert abs(float(sd(jnp.asarray(5))) - 0.1) < 1e-7
    assert abs(float(sd(jnp.asarray(15))) - 0.01) < 1e-7
    assert abs(float(sd(jnp.asarray(25))) - 0.001) < 1e-8
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.asarray(5))) < 1.0
    assert float(wc(jnp.asarray(10))) >= float(wc(jnp.asarray(90)))
    cd = cosine_decay(1.0, 100)
    assert float(cd(jnp.asarray(100))) <= 0.11


def test_comm_model_table1():
    """Paper Table 1 cost model identities."""
    m = CommModel(d=1_000_000, k=10_000, M=10)
    assert m.bits_per_iter("sgd") == 32 * m.d * m.M
    assert m.bits_per_iter("sparse") == 32 * m.k * m.M
    # SASG total with realized rounds R: 32 k R
    assert m.total_bits("sasg", T=100, sum_rounds=600) == 32 * m.k * 600
    assert m.total_bits("lasg", T=100, sum_rounds=600) == 32 * m.d * 600
    # SASG <= Sparse <= SGD orderings at equal rounds
    assert m.total_bits("sasg", 100, 1000) <= m.bits_per_iter("sparse") * 100
    assert m.bits_per_iter("sparse") <= m.bits_per_iter("sgd")


def test_link_model_table3():
    lm = LinkModel(bandwidth_bps=1e9, latency_s=0.0, sequential_uplink=True)
    # 10 dense uploads of 4e6 floats at 1 Gbps: ~1.28 s
    t_dense = lm.upload_time(32.0 * 4e6, 10)
    t_sparse = lm.upload_time(32.0 * 4e4, 10)
    assert t_dense / t_sparse == 100.0


def test_token_stream_learnable_structure():
    s = token_stream(vocab=32, batch=4, seq=64, seed=0, bigram_order=1.0)
    b = next(s)
    toks, labels = b["tokens"], b["labels"]
    assert toks.shape == (4, 64) and labels.shape == (4, 64)
    # labels are next tokens
    assert (toks[:, 1:] == labels[:, :-1]).all()
    # with bigram_order=1, successor is a function of current token
    mapping = {}
    for t, l in zip(toks.reshape(-1), labels.reshape(-1)):
        assert mapping.setdefault(int(t), int(l)) == int(l)


def test_sharded_loader_prefetch():
    src = token_stream(vocab=16, batch=2, seq=8, seed=1)
    loader = ShardedLoader(src, shardings=None, prefetch=2)
    b1, b2 = next(loader), next(loader)
    assert b1["tokens"].shape == (2, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    loader.close()


def test_synthetic_classification_learnable():
    x, y = synthetic_classification(256, 10, (28, 28, 1), seed=0, noise=0.1)
    # nearest-template classification should be near-perfect at low noise
    templates = np.stack([x[y == c].mean(0) for c in range(10)])
    pred = np.argmin(
        ((x[:, None] - templates[None]) ** 2).sum((2, 3, 4)), axis=1
    )
    assert (pred == y).mean() > 0.95

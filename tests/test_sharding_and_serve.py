"""Sharding-rule unit tests + serve engine integration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist.sharding import batch_specs, cache_specs, param_specs
from repro.models import build
from repro.serve import BatchedServer, Request, build_serve


def test_param_specs_roles(mesh2d):
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, mesh2d, "data", "model")
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_path = {
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp): v
        for kp, v in flat
    }
    embed = [v for k, v in by_path.items() if k.endswith("embed")][0]
    assert tuple(embed) == ("model", "data")
    wq = [v for k, v in by_path.items() if k.endswith("wq")][0]
    # stacked leading layer axis prepended as None
    assert tuple(wq)[-2:] == ("data", "model") or tuple(wq) == ("data", "model")
    norms = [v for k, v in by_path.items() if "norm1" in k]
    assert all(tuple(v) == () for v in norms)


def test_param_specs_stage_trunk():
    """Trunk leaves take the stage axis on the stacked layer dim with
    role-aware trailing dims; everything else ignores stage_axis."""
    import repro.compat

    mesh = repro.compat.make_mesh((2, 2, 2), ("data", "stage", "model"))
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    trunk = tuple(str(k) for k in model.pipeline.trunk_path)
    specs = param_specs(shapes, mesh, None, "model",
                        stage_axis="stage", trunk_paths=(trunk,))
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_path = {
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp): v
        for kp, v in flat
    }
    wq = [v for k, v in by_path.items() if k.startswith("unit/0") and k.endswith("wq")][0]
    assert tuple(wq)[0] == "stage" and tuple(wq)[-1] == "model"
    sc = [v for k, v in by_path.items() if k.startswith("unit/0") and k.endswith("scale")][0]
    assert tuple(sc)[0] == "stage"
    # non-trunk leaves never pick up the stage axis
    assert all(
        "stage" not in tuple(v)
        for k, v in by_path.items() if not k.startswith("unit/0")
    )
    # and an indivisible trunk depth (2 layers over... a fake 3-stage axis)
    mesh3 = repro.compat.make_mesh((3,), ("stage",))
    specs3 = param_specs(shapes, mesh3, None, None,
                         stage_axis="stage", trunk_paths=(trunk,))
    wq3 = jax.tree_util.tree_flatten_with_path(specs3)[0]
    wq3 = [v for kp, v in wq3 if "wq" in str(kp)][0]
    assert tuple(wq3)[0] is None  # 2 % 3 != 0 -> unsharded, not crashed


def test_param_specs_divisibility_fallback(mesh2d):
    """mixtral's 8 experts on a 16-way model axis must fall back to TP over
    d_expert (here: 8 experts on 2-way model axis still shard E; force the
    fallback with a fake axis size by checking a 3-expert config)."""
    from dataclasses import replace

    cfg = get_config("mixtral_8x7b").reduced()
    cfg = replace(cfg, moe=replace(cfg.moe, num_experts=3))
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, mesh2d, None, "model")
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    eg = [v for kp, v in flat if "experts_gate" in str(kp)][0]
    # E=3 not divisible by model=2 -> expert dim unsharded, d_expert sharded
    assert tuple(eg)[-3] is None and tuple(eg)[-1] == "model"


def test_batch_and_cache_specs(mesh2d):
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32)}
    bs = batch_specs(batch, mesh2d, "data")
    assert tuple(bs["tokens"])[0] == "data"
    cache = jax.eval_shape(lambda: model.init_cache(8, 32))
    cs = cache_specs(cache, mesh2d, "data", "model")
    leaves = jax.tree_util.tree_flatten_with_path(cs)[0]
    kspecs = [v for kp, v in leaves if "'k'" in str(kp)]
    assert kspecs and any(e == "data" for e in tuple(kspecs[0]) if e)


def test_serve_engine_batched_requests(mesh2d):
    cfg = get_config("internvl2_2b").reduced()
    model = build(cfg)
    serve = build_serve(model, mesh2d, fsdp="data", tp="model")
    params = jax.jit(model.init, out_shardings=serve.param_shardings)(
        jax.random.PRNGKey(0)
    )
    srv = BatchedServer(serve, params, cfg, batch_size=4, max_seq=64)
    rng = np.random.default_rng(0)
    for uid in range(6):  # more requests than slots: tests queuing
        req = Request(uid=uid,
                      prompt=rng.integers(0, cfg.vocab_size, size=5).astype(np.int32),
                      max_new_tokens=4)
        assert srv.submit(req)  # queue admission: always accepted (no cap)
    done, pending = srv.drain(max_ticks=200)
    assert len(done) == 6 and not pending
    for r in done:
        assert len(r["tokens"]) == 4
        assert all(0 <= t < cfg.vocab_size for t in r["tokens"])


def test_prefill_then_decode_consistency():
    """prefill's cache + one decode == forward over the full sequence."""
    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    B, S = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    logits_pf, cache = model.prefill(params, {"tokens": toks[:, :S]})
    # grow the cache to S+1 capacity? init_cache in prefill used S; decode at
    # pos S needs capacity: re-run prefill against a larger cache via decode loop
    cache = model.init_cache(B, S + 1)
    pos = 0
    for t in range(S):
        last, cache = model.decode_step(params, cache, toks[:, t : t + 1], jnp.asarray(t))
    from repro.models import lm as LM

    full, _ = LM.lm_forward(params, cfg, toks[:, : S])
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2
    )

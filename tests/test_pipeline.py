"""Pipeline parallelism: pipelined == sequential execution, values and grads.

Covers the bare GPipe kernel (``pipeline_apply`` over 2 and 4 stages on the
8-fake-device CPU mesh) and the SASG-facing composition helpers
(``build_pipelined_loss`` / ``build_pipelined_vag``): the stage-0 loss mask
plus psum/all-gather grad combine must reproduce the sequential loss AND the
full gradient tree on every stage.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.compat
from repro.dist.pipeline import (
    build_pipelined_forward,
    build_pipelined_vag,
    pipeline_apply,
    resolve_microbatches,
)
from repro.models.model import PipelineDef


def _layer_fn(w, h):
    return jnp.tanh(h @ w)


def _stage_mesh(S):
    return repro.compat.make_mesh((S,), ("stage",))


@pytest.mark.parametrize("S", [2, 4])
def test_pipeline_matches_sequential(S):
    L_per, n_micro, mb, d = 2, 6, 3, 8
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(S, L_per, d, d)).astype(np.float32) * 0.2)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))

    stage_fn = build_pipelined_forward(_layer_fn, L_per, axis="stage")

    def worker(wseg, micro_x):
        return pipeline_apply(stage_fn, wseg, micro_x, axis="stage")

    sm = jax.shard_map(
        worker, mesh=_stage_mesh(S),
        in_specs=(P("stage"), P()),
        out_specs=P(),
        axis_names={"stage"}, check_vma=False,
    )
    out_pipe = jax.jit(sm)(W.reshape(S * L_per, d, d), x)

    ref = x
    for l in range(S * L_per):
        ref = _layer_fn(W.reshape(S * L_per, d, d)[l], ref)
    np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S", [2, 4])
def test_pipeline_grads_match_sequential(S):
    """Grads THROUGH pipeline_apply (ppermute ring + psum transpose) equal
    the sequential stack's grads for both the stage params and the input."""
    L_per, n_micro, mb, d = 1, 4, 2, 6
    rng = np.random.default_rng(1)
    W = jnp.asarray(rng.normal(size=(S * L_per, d, d)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))

    stage_fn = build_pipelined_forward(_layer_fn, L_per, axis="stage")

    def worker(wseg, micro_x, tgt):
        def loss_fn(wseg_, micro_x_):
            out = pipeline_apply(stage_fn, wseg_, micro_x_, axis="stage")
            loss = jnp.mean((out - tgt) ** 2)
            # stage-0 mask: makes the psum below the uniform grad combine
            return jnp.where(jax.lax.axis_index("stage") == 0, loss, 0.0)

        loss, (gw, gx) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            wseg, micro_x
        )
        gw_full = jax.lax.all_gather(gw, "stage", axis=0, tiled=True)
        return (jax.lax.psum(loss, "stage"), gw_full,
                jax.lax.psum(gx, "stage"))

    sm = jax.shard_map(
        worker, mesh=_stage_mesh(S),
        in_specs=(P("stage"), P(), P()),
        out_specs=(P(), P(), P()),
        axis_names={"stage"}, check_vma=False,
    )
    loss_p, gw_p, gx_p = jax.jit(sm)(W, x, t)

    def ref_loss(W_, x_):
        h = x_
        for l in range(S * L_per):
            h = _layer_fn(W_[l], h)
        return jnp.mean((h - t) ** 2)

    loss_r, (gw_r, gx_r) = jax.value_and_grad(ref_loss, argnums=(0, 1))(W, x)
    np.testing.assert_allclose(float(loss_p), float(loss_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               rtol=1e-4, atol=1e-6)


def _toy_pdef(n_layers):
    """Synthetic PipelineDef with non-trunk params on both sides of the
    trunk, to exercise the psum (prepare/finish) vs all-gather (trunk) grad
    combine split in build_pipelined_vag."""
    return PipelineDef(
        n_layers=n_layers,
        trunk_path=("trunk",),
        prepare=lambda params, batch: batch["x"] @ params["w_in"],
        layer_fn=_layer_fn,
        finish=lambda params, h, batch: jnp.mean(
            (h @ params["w_out"] - batch["y"]) ** 2
        ),
    )


@pytest.mark.parametrize("S", [2, 4])
def test_pipelined_vag_full_tree(S):
    n_layers, b, d_in, d, d_out = 4, 8, 5, 6, 3
    rng = np.random.default_rng(2)
    params = {
        "w_in": jnp.asarray(rng.normal(size=(d_in, d)).astype(np.float32) * 0.4),
        "trunk": jnp.asarray(rng.normal(size=(n_layers, d, d)).astype(np.float32) * 0.3),
        "w_out": jnp.asarray(rng.normal(size=(d, d_out)).astype(np.float32) * 0.4),
    }
    batch = {
        "x": jnp.asarray(rng.normal(size=(b, d_in)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(b, d_out)).astype(np.float32)),
    }
    pdef = _toy_pdef(n_layers)
    vag = build_pipelined_vag(pdef, axis="stage")

    sm = jax.shard_map(
        vag, mesh=_stage_mesh(S),
        in_specs=({"w_in": P(), "trunk": P("stage"), "w_out": P()}, P()),
        out_specs=(P(), {"w_in": P(), "trunk": P(), "w_out": P()}),
        axis_names={"stage"}, check_vma=False,
    )
    loss_p, g_p = jax.jit(sm)(params, batch)

    def ref_loss(params_, batch_):
        h = pdef.prepare(params_, batch_)
        for l in range(n_layers):
            h = _layer_fn(params_["trunk"][l], h)
        return pdef.finish(params_, h, batch_)

    loss_r, g_r = jax.value_and_grad(ref_loss)(params, batch)
    np.testing.assert_allclose(float(loss_p), float(loss_r), rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(g_p[k]), np.asarray(g_r[k]), rtol=1e-4, atol=1e-6,
            err_msg=f"grad mismatch for {k}",
        )


def test_pipelined_loss_microbatch_fallback():
    """Batches that the configured microbatch count does not divide fall back
    to the gcd (the LASG probe sub-batch path)."""
    assert resolve_microbatches(8, 4) == 4
    assert resolve_microbatches(6, 4) == 3   # largest divisor <= requested
    assert resolve_microbatches(12, 8) == 6
    assert resolve_microbatches(7, 4) == 1
    assert resolve_microbatches(5, 1) == 1

    # and the loss builder runs end to end on a probe-sized (odd) batch
    n_layers, S = 2, 2
    rng = np.random.default_rng(3)
    params = {
        "w_in": jnp.asarray(rng.normal(size=(4, 6)).astype(np.float32) * 0.4),
        "trunk": jnp.asarray(rng.normal(size=(n_layers, 6, 6)).astype(np.float32) * 0.3),
        "w_out": jnp.asarray(rng.normal(size=(6, 2)).astype(np.float32) * 0.4),
    }
    batch = {
        "x": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32)),
    }
    pdef = _toy_pdef(n_layers)
    vag = build_pipelined_vag(pdef, axis="stage", microbatches=4)
    sm = jax.shard_map(
        vag, mesh=_stage_mesh(S),
        in_specs=({"w_in": P(), "trunk": P("stage"), "w_out": P()}, P()),
        out_specs=(P(), {"w_in": P(), "trunk": P(), "w_out": P()}),
        axis_names={"stage"}, check_vma=False,
    )
    loss_p, _ = jax.jit(sm)(params, batch)

    def ref_loss(params_):
        h = pdef.prepare(params_, batch)
        for l in range(n_layers):
            h = _layer_fn(params_["trunk"][l], h)
        return pdef.finish(params_, h, batch)

    np.testing.assert_allclose(float(loss_p), float(ref_loss(params)), rtol=1e-6)

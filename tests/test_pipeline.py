"""Pipeline parallelism: pipelined == sequential execution."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.pipeline import build_pipelined_forward, pipeline_apply


def test_pipeline_matches_sequential(mesh2d):
    # reuse the 4x2 mesh: treat 'data' as the stage axis (4 stages)
    S, L_per, n_micro, mb, d = 4, 2, 6, 3, 8
    rng = np.random.default_rng(0)
    # per-stage params: (S, L_per, d, d)
    W = jnp.asarray(rng.normal(size=(S, L_per, d, d)).astype(np.float32) * 0.2)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))

    def layer_fn(w, h):
        return jnp.tanh(h @ w)

    stage_fn = build_pipelined_forward(layer_fn, L_per, axis="data")

    def worker(wseg, micro_x):
        wseg = wseg[0]  # strip stage-stacked dim (manual shard)
        return pipeline_apply(stage_fn, wseg, micro_x, axis="data")

    sm = jax.shard_map(
        worker, mesh=mesh2d,
        in_specs=(P("data"), P()),
        out_specs=P(),
        axis_names={"data"}, check_vma=False,
    )
    out_pipe = jax.jit(sm)(W, x)

    # sequential reference: all S*L_per layers applied in order
    ref = x
    for s in range(S):
        for l in range(L_per):
            ref = jnp.tanh(ref @ W[s, l])
    np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

"""Smoke test for benchmarks/pipeline_bench.py (BENCH_pipeline.json shape).

One timed step per build keeps this a compile-bound smoke check; the point
is the record schema — in particular the stage-axis traffic SPLIT
(activation ring vs gradient payload gather) the PR-4/7 accounting work
introduced — not the timings.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import pipeline_bench  # noqa: E402


def test_pipeline_bench_splits_ring_and_gather(tmp_path):
    out = tmp_path / "BENCH_pipeline.json"
    rec = pipeline_bench.run(stages=2, steps=1, out_path=str(out))["pipeline"]
    on_disk = json.loads(out.read_text())
    assert on_disk == rec

    pipe = rec["pipelined"]
    # upload accounting identical flat vs pipelined (by construction)
    assert pipe["bits_wire_per_upload"] == rec["flat"]["bits_wire_per_upload"]
    # the stage-axis traffic decomposes exactly into ring + gather
    assert pipe["pipe_bits_per_step"] == pytest.approx(
        pipe["pipe_ring_bits_per_step"] + pipe["pipe_gather_bits_per_step"]
    )
    assert pipe["pipe_ring_bits_per_step"] > 0
    # gradient-exchange traffic is k-scale on the payload path: less than
    # one compressed upload per step (the old dense combine was ~15x it)
    assert 0 < pipe["pipe_gather_bits_per_step"] < pipe["bits_wire_per_upload"]

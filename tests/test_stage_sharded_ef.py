"""Stage-sharded EF + payload-level stage gather: the bit-conservation suite.

The pipelined hot path (comm.transport "stage composition") compresses the
stage-LOCAL trunk slice and gathers only the k-sized payload over the stage
axis; the EF residuals of trunk leaves live stage-sharded (d/S per device,
dist.sharding.ef_specs). Four properties pin that down:

1. support-exactness (hypothesis): encoding a stage's trunk slice with the
   as-if-full per-block k (``stage_dims``) selects exactly that slice of the
   flat run's support — concatenated stage payloads == the full payload,
   concatenated residuals == the full residual, bit-for-bit;
2. end-to-end: 2-stage pipelined runs reproduce the flat run (updates /
   sends / bits) for the payload path (topk_ef kernel AND reference, with
   the selection rule exercising the stage-psum'd ``diff_sq_norm``) and for
   a dense fallback with selection (qsgd) — via the shared
   ``flat_pipe_check`` harness;
3. EF placement + elastic remap: the trunk EF buffers are stage-sharded on
   device but FULL-shaped as logical arrays, so a checkpoint written under
   S stages restores under S' as pure resharding with bit-identical
   residuals (core.error_feedback.remap_error_state);
4. (slow) a 16-device 4-stage LM variant of the end-to-end check, in a
   subprocess so the device count can be forced before jax imports.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.compat
from repro.configs import get_config
from repro.core import sasg_config
from repro.core.compressors import CompressorConfig, build_compressor
from repro.core.error_feedback import remap_error_state
from repro.models import build


@pytest.fixture(scope="module")
def mesh_flat1d():
    return repro.compat.make_mesh((2,), ("data",))


@pytest.fixture(scope="module")
def mesh_pipe2():
    return repro.compat.make_mesh((2, 2), ("data", "stage"))


def _cnn_model(width=16):
    return build(dataclasses.replace(get_config("cnn_cifar"), d_model=width))


def _cnn_batches(n, b=8, seed=0):
    rng = np.random.default_rng(seed)
    return [{
        "x": jnp.asarray(rng.normal(size=(b, 32, 32, 3)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 10, size=(b,)).astype(np.int32)),
    } for _ in range(n)]


# ---------------------------------------------------------------------------
# 1. support-exactness of the stage-local encode
# ---------------------------------------------------------------------------

def _assert_stage_encode_matches_full(x, cfg, S, steps=2):
    """Concatenated stage-local (payload, residual) == the full-tensor run,
    bit-for-bit, across ``steps`` EF iterations."""
    L = x.shape[0]
    tree_full = {"w": jnp.asarray(x)}
    full = build_compressor(cfg)
    # stage compressors see the slice but must size k as if full
    local = build_compressor(cfg, stage_dims={"w": L})
    err_f = full.init(tree_full)
    errs = [
        local.init({"w": jnp.asarray(x[s * (L // S):(s + 1) * (L // S)])})
        for s in range(S)
    ]
    rng = np.random.default_rng(0)
    g = x
    for _ in range(steps):
        p_full, err_f = full.compress(err_f, {"w": jnp.asarray(g)}, None)
        parts = []
        for s in range(S):
            sl = g[s * (L // S):(s + 1) * (L // S)]
            p_s, errs[s] = local.compress(errs[s], {"w": jnp.asarray(sl)}, None)
            parts.append(p_s["w"])
        # identical blocked geometry (support-exactness prerequisite)
        assert all(
            tuple(p.blocked_shape[1:]) == tuple(p_full["w"].blocked_shape[1:])
            and p.values.shape[-1] == p_full["w"].values.shape[-1]
            for p in parts
        )
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p.values) for p in parts], axis=0),
            np.asarray(p_full["w"].values),
        )
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p.indices) for p in parts], axis=0),
            np.asarray(p_full["w"].indices),
        )
        np.testing.assert_array_equal(
            np.concatenate(
                [np.asarray(e["w"]) for e in errs], axis=0
            ),
            np.asarray(err_f["w"]),
        )
        g = rng.normal(size=x.shape).astype(np.float32)


@given(
    rows_per_stage=st.integers(1, 3),
    S=st.sampled_from([2, 4]),
    c=st.integers(6, 48),
    ratio=st.floats(0.01, 0.9),
    bs=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_stage_local_encode_support_exact(rows_per_stage, S, c, ratio, bs,
                                          seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(S * rows_per_stage, c)).astype(np.float32)
    cfg = CompressorConfig(name="topk_ef", k_ratio=ratio,
                           topk_impl="reference", block_size=bs)
    _assert_stage_encode_matches_full(x, cfg, S)


@pytest.mark.parametrize("impl", ["kernel", "reference"])
def test_stage_local_encode_support_exact_kb_rounding(impl):
    """The regression that motivates as-if-full kb: at ratio=0.023 with
    64-wide blocks, the full (2, 64) tensor rounds to k=3 over 2 blocks
    (kb=2) but a 1-row stage slice sized from itself would round to k=1
    over 1 block (kb=1) — a silently thinner payload. Both impls must ship
    the full run's support from the slice."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(2, 64)).astype(np.float32)
    cfg = CompressorConfig(name="topk_ef", k_ratio=0.023, topk_impl=impl,
                           block_size=64)
    _assert_stage_encode_matches_full(x, cfg, S=2)
    # and the naive slice-sized k really does differ (guards test strength)
    naive = build_compressor(cfg)
    p_naive, _ = naive.compress(
        naive.init({"w": jnp.asarray(x[:1])}), {"w": jnp.asarray(x[:1])}, None
    )
    stage = build_compressor(cfg, stage_dims={"w": 2})
    p_stage, _ = stage.compress(
        stage.init({"w": jnp.asarray(x[:1])}), {"w": jnp.asarray(x[:1])}, None
    )
    assert p_naive["w"].values.shape[-1] < p_stage["w"].values.shape[-1]


# ---------------------------------------------------------------------------
# 2. end-to-end: pipelined == flat through the real train step
# ---------------------------------------------------------------------------

_E2E = {
    # payload-gather hot path, selection ON: exercises the stage-local
    # encode, the k-sized payload gather, the stage-psum'd diff_sq_norm in
    # the send/skip rule, and the full-payload stale cache
    "topk_kernel_sel": dataclasses.replace(
        sasg_config(k_ratio=0.05, max_delay=4),
        compressor=dataclasses.replace(
            sasg_config(k_ratio=0.05, max_delay=4).compressor,
            topk_impl="kernel",
        ),
    ),
    "topk_reference_sel": dataclasses.replace(
        sasg_config(k_ratio=0.05, max_delay=4),
        compressor=dataclasses.replace(
            sasg_config(k_ratio=0.05, max_delay=4).compressor,
            topk_impl="reference",
        ),
    ),
    # dense-combine fallback WITH selection: qsgd has no stage-payload
    # support, so this pins the relocated collectives of the fallback path
    # (loss psum + stage_combine_leaf through repro.comm)
    "qsgd_sel": dataclasses.replace(
        sasg_config(k_ratio=0.05, max_delay=4),
        compressor=CompressorConfig(name="qsgd"),
    ),
}


@pytest.mark.parametrize("name", sorted(_E2E))
def test_stage_payload_end_to_end(name, mesh_flat1d, mesh_pipe2,
                                  flat_pipe_check):
    res = flat_pipe_check(
        _cnn_model(), _E2E[name], mesh_flat1d, mesh_pipe2, 2, _cnn_batches(3),
    )
    # the payload path's gather traffic is k-scale: well under one upload
    # per step; the fallback pays dense bits (the carried-over cost)
    mets = res["bp"].jit_step(res["sp"], _cnn_batches(1, seed=9)[0])[1]
    gather = float(mets["pipe_gather_bits_step"])
    assert gather > 0
    if name.startswith("topk"):
        assert gather < res["bp"].bits_wire
        assert res["bp"].exchange.transport.stage is not None
    else:
        assert res["bp"].exchange.transport.stage is None


# ---------------------------------------------------------------------------
# 3. EF placement + elastic remap
# ---------------------------------------------------------------------------

def test_trunk_ef_stage_sharded_and_remaps(mesh_flat1d, mesh_pipe2,
                                           flat_pipe_check):
    """On the payload path the trunk EF buffers are stage-sharded on device
    (each stage holds d/S residual rows) yet FULL-shaped logically; the
    stage-sharded "checkpoint" restores onto a different stage count (here
    S=2 -> flat) by pure resharding, every residual bit preserved."""
    res = flat_pipe_check(
        _cnn_model(), _E2E["topk_kernel_sel"], mesh_flat1d, mesh_pipe2, 2,
        _cnn_batches(3),
    )
    bp, bf, sp, sf = res["bp"], res["bf"], res["sp"], res["sf"]

    cs_pipe = sp.wstate.comp_state
    trunk = cs_pipe["trunk"]
    for leaf in jax.tree.leaves(trunk):
        # worker-stacked dim 0, stage-sharded trunk dim 1: d/S rows/device
        assert "stage" in str(leaf.sharding.spec)
        shard = leaf.addressable_shards[0]
        assert shard.data.shape[1] == leaf.shape[1] // 2
    # non-trunk EF (stem/gn0/head) never carries the stage axis
    for sub in ("stem", "gn0"):
        for leaf in jax.tree.leaves(cs_pipe[sub]):
            assert "stage" not in str(leaf.sharding.spec)

    # elastic restore: reshard the stage-sharded EF onto the flat mesh's EF
    # layout (S=1) and back — values bit-identical both ways
    cs_flat = remap_error_state(
        cs_pipe, jax.tree.map(lambda s: s.sharding, sf.wstate.comp_state)
    )
    for a, b in zip(jax.tree.leaves(cs_pipe), jax.tree.leaves(cs_flat)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert "stage" not in str(b.sharding.spec)
    cs_back = remap_error_state(
        cs_flat, jax.tree.map(lambda s: s.sharding, cs_pipe)
    )
    for a, b in zip(jax.tree.leaves(cs_pipe), jax.tree.leaves(cs_back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.sharding == b.sharding

    # and the stage-sharded residuals ARE the flat run's residuals (to the
    # tie-flip tolerance — same support by construction, property 1)
    for a, b in zip(jax.tree.leaves(cs_pipe),
                    jax.tree.leaves(sf.wstate.comp_state)):
        assert float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) < 2e-2


def test_remap_across_stage_counts_synthetic():
    """2 -> 4 -> 2 stage remap of a toy stage-sharded EF tree: device shard
    contents always equal the corresponding numpy rows (the full logical
    array is the invariant; placement is the only thing that changes)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist.sharding import ef_specs, param_specs

    mesh2 = repro.compat.make_mesh((2, 2), ("data", "stage"))
    mesh4 = repro.compat.make_mesh((2, 4), ("data", "stage"))
    tree = {"trunk": {"w": jnp.arange(4 * 8 * 8, dtype=jnp.float32)
                      .reshape(4, 8, 8)},
            "head": {"w": jnp.ones((8, 8), jnp.float32)}}
    ref = jax.tree.map(np.asarray, tree)

    def place(t, mesh):
        specs = ef_specs(
            param_specs(t, mesh, None, None, stage_axis="stage",
                        trunk_paths=(("trunk",),)),
            "stage", stage_sharded=True,
        )
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), t, specs,
            is_leaf=lambda x: isinstance(x, (jnp.ndarray, np.ndarray)),
        )

    t2 = place(tree, mesh2)
    t4 = remap_error_state(
        t2, jax.tree.map(lambda s: s.sharding, place(tree, mesh4))
    )
    assert t4["trunk"]["w"].addressable_shards[0].data.shape[0] == 1  # 4/S'
    t2b = remap_error_state(
        t4, jax.tree.map(lambda s: s.sharding, t2)
    )
    for t in (t2, t4, t2b):
        for k in ("trunk", "head"):
            np.testing.assert_array_equal(np.asarray(t[k]["w"]), ref[k]["w"])
    # fallback layout: stage stripped -> replicated over stages
    stripped = ef_specs(
        param_specs(tree, mesh2, None, None, stage_axis="stage",
                    trunk_paths=(("trunk",),)),
        "stage", stage_sharded=False,
    )
    assert all("stage" not in str(s) for s in jax.tree.leaves(
        stripped, is_leaf=lambda x: isinstance(x, P)))


def test_remap_stage_axis_shrinks_to_one():
    """Elastic restart with pipelining switched OFF: the checkpoint's EF
    specs still name the stage axis, but the restore mesh no longer carries
    it (or carries it at size 1 — meshes drop trivial axes when the topology
    shrinks). ``remap_error_state(..., mesh=...)`` accepts the recorded raw
    PartitionSpecs, strips the stale axis entries (sharding over a
    missing/size-1 axis IS replication), and the round trip back onto the
    pipelined mesh is bit-identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist.sharding import ef_specs, param_specs

    mesh2 = repro.compat.make_mesh((2, 2), ("data", "stage"))
    mesh_flat = repro.compat.make_mesh((2,), ("data",))

    tree = {"trunk": {"w": jnp.arange(4 * 8 * 8, dtype=jnp.float32)
                      .reshape(4, 8, 8)},
            "head": {"w": jnp.ones((8, 8), jnp.float32)}}
    ref = jax.tree.map(np.asarray, tree)
    specs2 = ef_specs(
        param_specs(tree, mesh2, None, None, stage_axis="stage",
                    trunk_paths=(("trunk",),)),
        "stage", stage_sharded=True,
    )
    assert "stage" in str(specs2["trunk"]["w"])  # the checkpoint-recorded specs
    t2 = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh2, s)), tree, specs2,
        is_leaf=lambda x: isinstance(x, (jnp.ndarray, np.ndarray)),
    )

    # stage axis gone entirely: old "stage"-naming specs bind onto the flat
    # mesh as replicated-over-the-missing-axis, values untouched
    t_flat = remap_error_state(t2, specs2, mesh=mesh_flat)
    for k in ("trunk", "head"):
        np.testing.assert_array_equal(np.asarray(t_flat[k]["w"]), ref[k]["w"])
        assert "stage" not in str(t_flat[k]["w"].sharding.spec)

    # stage axis present but size 1: same strip, same bits
    mesh_s1 = repro.compat.make_mesh((2, 1), ("data", "stage"))
    t_s1 = remap_error_state(t2, specs2, mesh=mesh_s1)
    for k in ("trunk", "head"):
        np.testing.assert_array_equal(np.asarray(t_s1[k]["w"]), ref[k]["w"])

    # and back onto the pipelined mesh: bit-identical, stage-sharded again
    t2b = remap_error_state(t_flat, specs2, mesh=mesh2)
    for k in ("trunk", "head"):
        np.testing.assert_array_equal(np.asarray(t2b[k]["w"]), ref[k]["w"])
    assert "stage" in str(t2b["trunk"]["w"].sharding.spec)
    assert t2b["trunk"]["w"].addressable_shards[0].data.shape[0] == 4 // 2

    # raw specs without a mesh is an error, not a silent crash downstream
    with pytest.raises(ValueError, match="PartitionSpec"):
        remap_error_state(t2, specs2)


# ---------------------------------------------------------------------------
# 4. 16-device 4-stage LM variant (subprocess: device count must be forced
#    before jax imports; conftest pins the session to 8)
# ---------------------------------------------------------------------------

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
import repro.compat
from repro.configs import get_config
from repro.core import sasg_config
from repro.data import token_stream
from repro.dist.strategy import choose_strategy
from repro.models import build
from repro.optim import constant
from repro.train import build_train_step

cfg = dataclasses.replace(get_config("llama3_8b").reduced(), n_layers=4)
model = build(cfg)
scfg = sasg_config(k_ratio=0.05, max_delay=4)

mesh_flat = repro.compat.make_mesh((2, 2), ("data", "model"))
mesh_pipe = repro.compat.make_mesh((2, 4, 2), ("data", "stage", "model"))

s_flat = choose_strategy(mesh_flat, sasg_enabled=True)
s_pipe = choose_strategy(mesh_pipe, sasg_enabled=True, pipeline_stages=4,
                         trunk_layers=model.pipeline.n_layers)
assert s_pipe.pipelined and s_pipe.pipeline_stages == 4
assert s_flat.num_workers == s_pipe.num_workers == 2

bf = build_train_step(model, scfg, mesh_flat, s_flat, constant(0.05))
bp = build_train_step(model, scfg, mesh_pipe, s_pipe, constant(0.05))
# 4-stage payload path engaged: k-sized gather, not the dense combine
assert bp.exchange.transport.stage is not None
assert bp.exchange.transport.stage.num_stages == 4
assert bf.bits_wire == bp.bits_wire and bf.bits_paper == bp.bits_paper

sf, sp = bf.init(jax.random.PRNGKey(0)), bp.init(jax.random.PRNGKey(0))

def max_diff(sa, sb):
    return max(
        float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params))
    )

assert max_diff(sf, sp) == 0.0
stream = token_stream(cfg.vocab_size, 8, 32, seed=0)
for _ in range(3):
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    sf, mf = bf.jit_step(sf, batch)
    sp, mp = bp.jit_step(sp, batch)
    assert float(mf["num_sent"]) == float(mp["num_sent"]), "send decisions diverged"
    d = max_diff(sf, sp)
    assert d < 2e-2, f"params diverged: {d}"
    assert float(mp["pipe_gather_bits_step"]) < bp.bits_wire
assert float(sf.counters.rounds) == float(sp.counters.rounds)
np.testing.assert_allclose(float(sf.counters.bits_wire),
                           float(sp.counters.bits_wire), rtol=1e-6)
# stage-sharded EF: trunk residuals hold 1/4 of the layer stack per stage
trunk = sp.wstate.comp_state["unit"][0]
for leaf in jax.tree.leaves(trunk):
    assert leaf.addressable_shards[0].data.shape[1] == leaf.shape[1] // 4
print("STAGE_EF_4STAGE_OK")
"""


@pytest.mark.slow
def test_lm_4stage_payload_path_matches_flat():
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SCRIPT)],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert p.returncode == 0 and "STAGE_EF_4STAGE_OK" in p.stdout, (
        f"stdout:\n{p.stdout[-4000:]}\nstderr:\n{p.stderr[-4000:]}"
    )

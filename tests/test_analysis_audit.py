"""End-to-end checks for the HLO collective auditor (repro.analysis.hlo_audit).

Compiles the real train step on the CPU test meshes, so these are the
slowest analysis tests (~1 min/cell). Three properties:

- the seed matrix is clean: HLO exchange wire bytes match the analytic
  ``bits_wire`` counters within tolerance and nothing d-sized escapes the
  accounted exchange on flat cells;
- injected counter drift (>1%) fails the gate;
- an injected d-sized collective on the exchange path fails the gate.
"""
import jax
import pytest

from repro.analysis import hlo_audit
from repro.analysis.hlo_audit import (
    AuditCell,
    audit_built,
    audit_cell,
    check_report,
)


@pytest.fixture(scope="module")
def flat_cell():
    cell = AuditCell(name="cnn_flat_sasg")
    model, mesh, strategy, built = hlo_audit._build_cell(cell)
    hlo = hlo_audit._compile_hlo(cell, mesh, built)
    return cell, mesh, strategy, built, hlo


def test_flat_cell_exchange_matches_counters(flat_cell):
    cell, mesh, strategy, built, hlo = flat_cell
    rec = audit_built(cell, mesh, strategy, built, hlo)
    assert rec["exchange_kind"] == "sparse"
    assert rec["hlo_exchange_wire_bytes"] > 0
    assert rec["drift_ok"], rec
    # measured on the seed: the gather wires EXACTLY bits_wire/8 per device
    assert rec["drift"] == pytest.approx(0.0, abs=1e-9)
    assert rec["dsized_ok"] and rec["dsized_collectives"] == []
    assert check_report({"cells": {cell.name: rec}, "tolerance": 0.01}) == []


def test_injected_counter_drift_fails_gate(flat_cell):
    cell, mesh, strategy, built, hlo = flat_cell
    # a 5% error in the analytic wire accounting (e.g. a forgotten index
    # byte) must trip the 1% gate
    tampered = built._replace(bits_wire=built.bits_wire * 1.05)
    rec = audit_built(cell, mesh, strategy, tampered, hlo)
    assert not rec["drift_ok"]
    problems = check_report({"cells": {cell.name: rec}, "tolerance": 0.01})
    assert problems and "drift" in problems[0]


def test_injected_dsized_collective_fails_gate(monkeypatch):
    # smuggle a worker-axis pmean of the DENSE update into the transport:
    # exactly the "d-sized collective on the exchange path" regression the
    # auditor exists to catch
    from repro.comm.transport import Transport

    orig = Transport.densify

    def rogue(self, contrib, like):
        out = orig(self, contrib, like)
        return jax.tree.map(
            lambda x: jax.lax.pmean(x, self.worker_axes), out
        )

    monkeypatch.setattr(Transport, "densify", rogue)
    cell = AuditCell(name="cnn_flat_sasg_rogue")
    model, mesh, strategy, built = hlo_audit._build_cell(cell)
    hlo = hlo_audit._compile_hlo(cell, mesh, built)
    rec = audit_built(cell, mesh, strategy, built, hlo)
    assert not rec["dsized_ok"]
    assert rec["dsized_collectives"], "rogue pmean not itemized"
    kinds = {r["kind"] for r in rec["dsized_collectives"]}
    assert "all-reduce" in kinds
    assert all("data" in r["axes"] for r in rec["dsized_collectives"])
    problems = check_report({"cells": {cell.name: rec}, "tolerance": 0.01})
    assert problems and "d-sized" in problems[0]


def test_pipelined_cell_rings_are_itemized_not_fatal():
    cell = AuditCell(
        name="cnn_pipe2_sasg",
        mesh_shape=(2, 2), mesh_axes=("data", "stage"),
        pipeline_stages=2, allow_dsized=True,
    )
    rec = audit_cell(cell)
    assert rec["drift_ok"], rec
    # the GPipe ring + stage gradient combine ARE d-sized — itemized,
    # attributed to the stage axis, and allowed on this cell
    assert rec["dsized_collectives"]
    assert rec["dsized_ok"]
    assert rec["ring_permute_wire_bytes"] > 0
    assert rec["stage_axis_wire_bytes"] >= rec["ring_permute_wire_bytes"]
    assert rec["pipe_model_bytes_per_step"] > 0
    assert all(
        "stage" in r["axes"] for r in rec["dsized_collectives"]
    ), rec["dsized_collectives"]
    assert check_report({"cells": {cell.name: rec}, "tolerance": 0.01}) == []

"""End-to-end checks for the HLO collective auditor (repro.analysis.hlo_audit).

Compiles the real train step on the CPU test meshes, so these are the
slowest analysis tests (~1 min/cell). Three properties:

- the seed matrix is clean: HLO exchange wire bytes match the analytic
  ``bits_wire`` counters within tolerance and nothing d-sized escapes the
  accounted exchange on flat cells;
- injected counter drift (>1%) fails the gate;
- an injected d-sized collective on the exchange path fails the gate.
"""
import jax
import pytest

from repro.analysis import hlo_audit
from repro.analysis.hlo_audit import (
    AuditCell,
    audit_built,
    audit_cell,
    check_report,
)


@pytest.fixture(scope="module")
def flat_cell():
    cell = AuditCell(name="cnn_flat_sasg")
    model, mesh, strategy, built = hlo_audit._build_cell(cell)
    hlo = hlo_audit._compile_hlo(cell, mesh, built)
    return cell, mesh, strategy, built, hlo


def test_flat_cell_exchange_matches_counters(flat_cell):
    cell, mesh, strategy, built, hlo = flat_cell
    rec = audit_built(cell, mesh, strategy, built, hlo)
    assert rec["exchange_kind"] == "sparse"
    assert rec["hlo_exchange_wire_bytes"] > 0
    assert rec["drift_ok"], rec
    # measured on the seed: the gather wires EXACTLY bits_wire/8 per device
    assert rec["drift"] == pytest.approx(0.0, abs=1e-9)
    assert rec["dsized_ok"] and rec["dsized_collectives"] == []
    assert check_report({"cells": {cell.name: rec}, "tolerance": 0.01}) == []


def test_injected_counter_drift_fails_gate(flat_cell):
    cell, mesh, strategy, built, hlo = flat_cell
    # a 5% error in the analytic wire accounting (e.g. a forgotten index
    # byte) must trip the 1% gate
    tampered = built._replace(bits_wire=built.bits_wire * 1.05)
    rec = audit_built(cell, mesh, strategy, tampered, hlo)
    assert not rec["drift_ok"]
    problems = check_report({"cells": {cell.name: rec}, "tolerance": 0.01})
    assert problems and "drift" in problems[0]


def test_injected_dsized_collective_fails_gate(monkeypatch):
    # smuggle a worker-axis pmean of the DENSE update into the transport:
    # exactly the "d-sized collective on the exchange path" regression the
    # auditor exists to catch
    from repro.comm.transport import Transport

    orig = Transport.densify

    def rogue(self, contrib, like):
        out = orig(self, contrib, like)
        return jax.tree.map(
            lambda x: jax.lax.pmean(x, self.worker_axes), out
        )

    monkeypatch.setattr(Transport, "densify", rogue)
    cell = AuditCell(name="cnn_flat_sasg_rogue")
    model, mesh, strategy, built = hlo_audit._build_cell(cell)
    hlo = hlo_audit._compile_hlo(cell, mesh, built)
    rec = audit_built(cell, mesh, strategy, built, hlo)
    assert not rec["dsized_ok"]
    assert rec["dsized_collectives"], "rogue pmean not itemized"
    kinds = {r["kind"] for r in rec["dsized_collectives"]}
    assert "all-reduce" in kinds
    assert all("data" in r["axes"] for r in rec["dsized_collectives"])
    problems = check_report({"cells": {cell.name: rec}, "tolerance": 0.01})
    assert problems and "d-sized" in problems[0]


@pytest.fixture(scope="module")
def pipe_cell_record():
    # the STRICT default matrix cell: allow_dsized is off since the
    # payload-level stage gather landed
    cell = AuditCell(
        name="cnn_pipe2_sasg",
        mesh_shape=(2, 2), mesh_axes=("data", "stage"),
        pipeline_stages=2,
    )
    return cell, audit_cell(cell)


def test_pipelined_cell_is_clean_rings_itemized(pipe_cell_record):
    """Post payload-gather: the ONLY d-sized stage-axis traffic is the GPipe
    activation ring, classified out of the fatal list and itemized under
    ring_collectives; the strict gate passes with zero forbidden ops."""
    cell, rec = pipe_cell_record
    assert rec["drift_ok"], rec
    assert not rec["allow_dsized"]
    assert rec["dsized_collectives"] == [] and rec["dsized_ok"]
    # both ring op kinds present: per-tick ppermute carries + the
    # output-replicating psum (result == the prepare activation block)
    kinds = {r["kind"] for r in rec["ring_collectives"]}
    assert kinds == {"collective-permute", "all-reduce"}
    assert all("stage" in r["axes"] for r in rec["ring_collectives"])
    assert rec["ring_wire_bytes"] > 0
    assert rec["pipe_model_bytes_per_step"] > 0
    assert check_report({"cells": {cell.name: rec}, "tolerance": 0.01}) == []


def test_stage_gradient_traffic_is_k_sized(pipe_cell_record):
    """The bit-conservation regression: stage-axis GRADIENT wire bytes
    (everything on the stage axis minus the activation ring) must stay
    under 2x one compressed upload — the payload gather is k-scale, where
    the old dense stage combine moved ~15x the upload."""
    cell, rec = pipe_cell_record
    grad = rec["stage_grad_wire_bytes"]
    assert grad == pytest.approx(
        rec["stage_axis_wire_bytes"] - rec["ring_wire_bytes"]
    )
    assert 0 < grad <= 2 * rec["bits_wire"] / 8.0, rec


def test_reintroduced_dsized_trunk_exchange_fails_gate(monkeypatch):
    """Injection: smuggle a d-sized stage-axis collective back into the
    gradient path (a dense psum of the update over the stage axis — the
    moral equivalent of the old trunk gather). The ring classifier must NOT
    absorb it, and the strict pipelined cell must fail check_report."""
    from repro.comm.transport import Transport

    orig = Transport.densify

    def rogue(self, contrib, like):
        out = orig(self, contrib, like)
        if self.stage is not None:
            s = self.stage
            return jax.tree.map(
                lambda x: jax.lax.psum(x, s.axis) / s.num_stages, out
            )
        return out

    monkeypatch.setattr(Transport, "densify", rogue)
    cell = AuditCell(
        name="cnn_pipe2_sasg_rogue",
        mesh_shape=(2, 2), mesh_axes=("data", "stage"),
        pipeline_stages=2,
    )
    rec = audit_cell(cell)
    assert not rec["dsized_ok"]
    assert rec["dsized_collectives"], "rogue stage psum not itemized"
    assert all("stage" in r["axes"] for r in rec["dsized_collectives"])
    problems = check_report({"cells": {cell.name: rec}, "tolerance": 0.01})
    assert problems and "d-sized" in problems[0]

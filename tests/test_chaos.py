"""Chaos suite: every single-fault FaultPlan recovers within max_restarts
and without silent state divergence (DESIGN.md §5).

Fault classes whose recovery replays the exact batch sequence from an
exactly-restored state (crash, data hiccup, save failures, checkpoint
corruption) must end bit-identical to an uninterrupted run. Classes that
change the update history by design (straggler skips, membership resizes)
are instead asserted deterministic — the same plan twice gives bit-identical
params — and complete."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import PRESETS
from repro.data import indexed_classification_stream
from repro.data.synthetic import synthetic_classification
from repro.models import build
from repro.optim import constant
from repro.train import (
    ElasticTrainer,
    FaultPlan,
    Trainer,
    TrainerConfig,
    WorkerMembership,
)

TOTAL, EVERY, FAULT_STEP = 12, 4, 7
SEED_DATA, SEED_INIT = 3, 7

MATRIX = FaultPlan.single_fault_matrix(step=FAULT_STEP, workers=4)
# recovery-replay classes: must be bit-identical to the uninterrupted run
BITEXACT = {
    "crash", "corrupt_ckpt", "save_fail_transient", "save_fail_lost",
    "data_hiccup",
}


def _pdiff(sa, sb):
    return max(
        float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params))
    )


@pytest.fixture(scope="module")
def harness(tmp_path_factory):
    cfg = get_config("fc_mnist")
    model = build(cfg)
    scfg = PRESETS["sasg"](k_ratio=0.1)
    xs, ys = synthetic_classification(256, cfg.vocab_size, (28, 28, 1), seed=0)
    mem = WorkerMembership(model, scfg, constant(0.05), sasg_enabled=True)

    def run(ckpt_dir, plan=None):
        tc = TrainerConfig(
            total_steps=TOTAL, ckpt_dir=ckpt_dir, ckpt_every=EVERY,
            log_every=10**9, record_batches=True,
        )
        tr = ElasticTrainer(
            mem.build(4),
            indexed_classification_stream(xs, ys, batch=8, seed=SEED_DATA),
            tc, membership=mem, plan=plan, log_fn=lambda s: None,
        )
        state = tr.run(init_key=jax.random.PRNGKey(SEED_INIT))
        return tr, state

    clean_tr, clean_state = run(str(tmp_path_factory.mktemp("clean")))
    return run, clean_tr, clean_state


@pytest.mark.parametrize("name", sorted(MATRIX))
def test_single_fault_recovers_without_divergence(name, harness, tmp_path):
    run, clean_tr, clean_state = harness
    tr, state = run(str(tmp_path / name), plan=MATRIX[name])

    # recovered within the restart budget, reached the end of the run
    assert len([e for e in tr.events if e["kind"] == "recovery"]) <= \
        tr.cfg.max_restarts
    assert tr.batch_log[-1][0] == TOTAL - 1  # reached the end of the run

    # replay integrity: every step index was applied (coverage), and the
    # batch applied at each step is the batch the uninterrupted run applied
    # there — zero skipped, zero duplicated. (The log may contain a
    # pre-failure prefix twice; what matters is the batch content per step.)
    assert dict(tr.batch_log) == dict(clean_tr.batch_log)
    assert sorted(dict(tr.batch_log)) == list(range(TOTAL))

    if name in BITEXACT:
        assert _pdiff(state, clean_state) == 0.0, (
            f"{name}: recovery silently diverged from the clean run"
        )
    else:
        # history-changing faults: assert determinism instead (same plan
        # twice -> bit-identical), and that the fault actually engaged
        tr2, state2 = run(str(tmp_path / (name + "_replay")), plan=MATRIX[name])
        assert _pdiff(state, state2) == 0.0, f"{name}: plan is not deterministic"

    if name == "worker_drop":
        assert any(e["kind"] == "resize" for e in tr.events)
        assert tr.built.strategy.num_workers == 2
    if name == "straggler":
        assert any(e["kind"] == "straggler" for e in tr.events)
        # the masked steps must force the skip path: num_sent strictly below
        # the worker count on every faulted step
        f = MATRIX[name].faults[0]
        for s in range(f.step, f.step + f.duration):
            assert tr.history[s]["num_sent"] < 4
    if name == "corrupt_ckpt":
        assert any(e["kind"] == "corrupt_ckpt" for e in tr.events)
    if name.startswith("save_fail"):
        assert any(e["kind"] == "save_fail_armed" for e in tr.events)
        if name == "save_fail_lost":
            assert any(e["kind"] == "ckpt_lost" for e in tr.events)
        else:
            assert not any(e["kind"] == "ckpt_lost" for e in tr.events)


def test_composed_plan_recovers(harness, tmp_path):
    """Faults compose: a straggler window, a crash, and a data hiccup in one
    plan still complete within the restart budget, deterministically."""
    run, clean_tr, _ = harness
    plan = (
        FaultPlan().straggler(5, indices=(1,), duration=2)
        .crash(7).data_hiccup(9)
    )
    tr, state = run(str(tmp_path / "composed"), plan=plan)
    recoveries = [e for e in tr.events if e["kind"] == "recovery"]
    assert len(recoveries) == 2
    assert dict(tr.batch_log) == dict(clean_tr.batch_log)
    tr2, state2 = run(str(tmp_path / "composed2"), plan=plan)
    assert _pdiff(state, state2) == 0.0


def test_plain_trainer_still_runs_with_iterator_data(harness, tmp_path):
    """Legacy path: a non-seekable generator keeps working (lossy replay,
    one-time warning) — the hardened loop is backward compatible."""
    run, clean_tr, _ = harness
    cfg = get_config("fc_mnist")
    model = build(cfg)
    scfg = PRESETS["sasg"](k_ratio=0.1)
    mem = WorkerMembership(model, scfg, constant(0.05), sasg_enabled=True)
    xs, ys = synthetic_classification(64, cfg.vocab_size, (28, 28, 1), seed=0)

    def gen():
        rng = np.random.default_rng(0)
        while True:
            idx = rng.integers(0, 64, size=8)
            yield {"x": xs[idx], "labels": ys[idx]}

    logs = []
    fail_once = {2}

    def fault(step):
        if step in fail_once:
            fail_once.discard(step)
            raise RuntimeError("injected")

    tc = TrainerConfig(total_steps=4, ckpt_dir=str(tmp_path / "gen"),
                       ckpt_every=2, log_every=10**9)
    tr = Trainer(mem.build(4), gen(), tc, fault_hook=fault, log_fn=logs.append)
    tr.run(init_key=jax.random.PRNGKey(0))
    assert len(tr.history) == 4
    # recovery on a non-seekable source warns exactly once (lossy replay)
    assert sum("not seekable" in ln for ln in logs) == 1

"""Pipeline x SASG composition: the pipelined train step must reproduce the
non-pipelined step on paper-mode configs.

Equality tiers (see dist/pipeline.py):

- LASG (identity compressor): the pipelined gradients equal the sequential
  ones up to fp32 reassociation (~1e-7), and nothing downstream is discrete,
  so updates / send decisions / counters match essentially bitwise.
- SASG (top-k + EF): the same ~1e-7 gradient reassociation can flip a top-k
  index at a near-tied magnitude boundary, after which error feedback keeps
  the runs slightly apart. Send/skip decisions and the (static-per-upload)
  bits counters still match exactly; params match to a tie-flip tolerance.

The equality loop itself lives in the shared ``flat_pipe_check`` fixture
(conftest.py) so the stage-sharded-EF suite runs the identical acceptance
check.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.compat
from conftest import max_param_diff
from repro.configs import get_config
from repro.core import (
    CompressorConfig,
    SASGConfig,
    SelectionConfig,
    lasg_config,
    sasg_config,
)
from repro.data import token_stream
from repro.dist.strategy import Strategy, choose_strategy
from repro.models import build
from repro.optim import constant
from repro.train import build_train_step


@pytest.fixture(scope="module")
def mesh_flat1d():
    return repro.compat.make_mesh((2,), ("data",))


@pytest.fixture(scope="module")
def mesh_pipe2():
    return repro.compat.make_mesh((2, 2), ("data", "stage"))


def _cnn_model(width=16):
    # smoke-sized cnn_cifar: same wiring, narrow enough for CPU compiles
    return build(dataclasses.replace(get_config("cnn_cifar"), d_model=width))


def _cnn_batches(n, b=8, seed=0):
    rng = np.random.default_rng(seed)
    return [{
        "x": jnp.asarray(rng.normal(size=(b, 32, 32, 3)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 10, size=(b,)).astype(np.int32)),
    } for _ in range(n)]


def test_pipelined_lasg_cnn_matches_flat_bitwise(
    mesh_flat1d, mesh_pipe2, flat_pipe_check
):
    """Paper-mode LASG: 2-stage pipelined step == flat step (same update,
    same send/skip decisions, same counters) within fp32 reassociation."""
    flat_pipe_check(
        _cnn_model(), lasg_config(max_delay=4), mesh_flat1d, mesh_pipe2, 2,
        _cnn_batches(4), param_tol=1e-6, loss_rtol=1e-5,
    )


def test_pipelined_sasg_cnn_matches_flat(mesh_flat1d, mesh_pipe2,
                                         flat_pipe_check):
    """Paper-mode SASG (top-k + EF + selection): decisions and bits match
    exactly; params to the top-k tie-flip tolerance (module docstring)."""
    flat_pipe_check(
        _cnn_model(), sasg_config(k_ratio=0.05, max_delay=4),
        mesh_flat1d, mesh_pipe2, 2, _cnn_batches(4),
    )


@pytest.mark.slow
def test_pipelined_lm_4stage_skip_rounds(flat_pipe_check):
    """4-stage pipelined SASG on the reduced llama trunk: skip rounds reuse
    the cached stale payload under pipelining and stay bit-identical to the
    flat run (dense identity compressor -> no tie flips)."""
    cfg = dataclasses.replace(get_config("llama3_8b").reduced(), n_layers=4)
    model = build(cfg)
    assert model.pipeline is not None and model.pipeline.n_layers == 4
    mesh_flat = repro.compat.make_mesh((2, 2), ("data", "model"))
    mesh_pipe = repro.compat.make_mesh((2, 4), ("data", "stage"))
    stream = token_stream(cfg.vocab_size, 8, 32, seed=0)
    batches = [
        {k: jnp.asarray(v) for k, v in next(stream).items()} for _ in range(3)
    ]
    res = flat_pipe_check(
        model, lasg_config(max_delay=4), mesh_flat, mesh_pipe, 4, batches,
        param_tol=1e-5, loss_rtol=1e-5,
    )
    # first round always uploads; later rounds must include actual skips so
    # the stale-payload reuse path is exercised under pipelining
    assert res["sents"][0] == 2.0
    assert min(res["sents"][1:]) == 0.0


def test_forced_skip_reuses_stale_payload_pipelined(mesh_flat1d, mesh_pipe2,
                                                    flat_pipe_check):
    """Huge alphas force the skip branch after the mandatory first upload:
    every worker replays its cached payload, and the pipelined replay matches
    the flat one exactly (payloads are cached, not recomputed)."""
    scfg = sasg_config(k_ratio=0.05, max_delay=4)
    scfg = dataclasses.replace(
        scfg, selection=dataclasses.replace(scfg.selection, alphas=(1e12,) * 4)
    )
    res = flat_pipe_check(
        _cnn_model(), scfg, mesh_flat1d, mesh_pipe2, 2, _cnn_batches(3),
    )
    assert res["sents"] == [2.0, 0.0, 0.0]
    # skip steps add zero algorithmic rounds in BOTH runs
    assert float(res["sf"].counters.rounds) == 2.0


def test_stage_knob_fallbacks(mesh_flat1d, mesh_pipe2):
    """choose_strategy degrades the pipeline knob exactly like the fit
    fallback: missing stage axis, indivisible trunk, or plain strategy."""
    # no stage axis in the mesh -> knob dropped
    s = choose_strategy(mesh_flat1d, sasg_enabled=True, pipeline_stages=2)
    assert not s.pipelined and s.stage_axis is None
    # stage axis but trunk depth does not divide -> dropped
    s = choose_strategy(mesh_pipe2, sasg_enabled=True, pipeline_stages=2,
                        trunk_layers=3)
    assert not s.pipelined
    # model with no pipelineable trunk (trunk_layers=0, e.g. fc_mnist) ->
    # dropped instead of erroring later in build_train_step
    s = choose_strategy(mesh_pipe2, sasg_enabled=True, pipeline_stages=2,
                        trunk_layers=0)
    assert not s.pipelined
    # divisible trunk -> engaged, stage size wins over the requested count
    s = choose_strategy(mesh_pipe2, sasg_enabled=True, pipeline_stages=8,
                        trunk_layers=4)
    assert s.pipelined and s.pipeline_stages == 2
    # plain fallback (params too large to worker-replicate) never pipelines
    s = choose_strategy(mesh_pipe2, sasg_enabled=True, params_bytes=10**14,
                        pipeline_stages=2, trunk_layers=4)
    assert s.name == "plain" and not s.pipelined
    # the stage axis still shrinks the replica fit denominator when engaged
    budget = 3 * 10**6  # REPLICA_OVERHEAD * 1e6 fits only when halved
    s = choose_strategy(mesh_pipe2, sasg_enabled=True, params_bytes=2 * 10**6,
                        replica_budget_bytes=budget,
                        pipeline_stages=2, trunk_layers=4)
    assert s.name == "flat" and s.pipelined
    s = choose_strategy(mesh_flat1d, sasg_enabled=True, params_bytes=2 * 10**6,
                        replica_budget_bytes=budget)
    assert s.name == "plain"


def test_build_train_step_rejects_bad_pipeline_configs(mesh_pipe2):
    """Hand-built strategies that cannot pipeline fail eagerly."""
    model = _cnn_model()
    scfg = sasg_config(k_ratio=0.05, max_delay=4)
    bad = Strategy("flat", ("data",), ("data",), None, None, None, 2,
                   stage_axis="stage", pipeline_stages=3)
    with pytest.raises(ValueError, match="does not divide"):
        build_train_step(model, scfg, mesh_pipe2, bad, constant(0.05))

    fc = build(get_config("fc_mnist"))
    assert fc.pipeline is None
    ok2 = Strategy("flat", ("data",), ("data",), None, None, None, 2,
                   stage_axis="stage", pipeline_stages=2)
    with pytest.raises(ValueError, match="PipelineDef"):
        build_train_step(fc, scfg, mesh_pipe2, ok2, constant(0.05))

    # the old topk_impl/bucket guard is gone: flat-vector sparse layouts now
    # densify against the transport's full-gradient template, so they build
    # (and match the flat run — test_pipelined_compressors_match_flat)
    flat_comp = dataclasses.replace(
        scfg, compressor=dataclasses.replace(scfg.compressor, topk_impl="exact")
    )
    built = build_train_step(model, flat_comp, mesh_pipe2, ok2, constant(0.05))
    assert built.exchange.transport.layout == "per_tensor"


# every sparse layout x impl (plus the stochastic baselines) must reproduce
# the flat run under pipelining — the transport seam's acceptance matrix
_COMPRESSORS = {
    "topk_kernel": CompressorConfig(name="topk_ef", k_ratio=0.05,
                                    topk_impl="kernel", block_size=64),
    "topk_reference": CompressorConfig(name="topk_ef", k_ratio=0.05,
                                       topk_impl="reference", block_size=64),
    "topk_exact_per_tensor": CompressorConfig(name="topk_ef", k_ratio=0.05,
                                              layout="per_tensor",
                                              topk_impl="exact"),
    "topk_flat_global": CompressorConfig(name="topk_ef", k_ratio=0.05,
                                         bucket="global", topk_impl="exact"),
    "randk": CompressorConfig(name="randk", k_ratio=0.05),
    "qsgd": CompressorConfig(name="qsgd"),
}


@pytest.mark.parametrize("comp", sorted(_COMPRESSORS))
def test_pipelined_compressors_match_flat(comp, mesh_flat1d, mesh_pipe2,
                                          flat_pipe_check):
    """2-stage pipelined step == flat step for every compressor layout the
    old train/step.py guard used to reject (plus the per-shard defaults):
    same sends, same bits counters, params to the tie-flip tolerance. The
    per-shard topk variants take the payload-gather hot path; everything
    else takes the dense-combine fallback."""
    model = _cnn_model()
    scfg = SASGConfig(compressor=_COMPRESSORS[comp],
                      selection=SelectionConfig(enabled=False), name=comp)
    res = flat_pipe_check(model, scfg, mesh_flat1d, mesh_pipe2, 2,
                          _cnn_batches(3))
    payload_path = comp in ("topk_kernel", "topk_reference")
    assert (res["bp"].exchange.transport.stage is not None) == payload_path


def test_kernel_and_reference_impls_agree_pipelined(mesh_pipe2):
    """The fused Pallas per-shard path (topk_impl='kernel', the default) is
    bit-compatible with the unfused reference through the full pipelined
    train step: same sends, same bits, same params."""
    model = _cnn_model()
    built = {}
    for impl in ("kernel", "reference"):
        scfg = sasg_config(k_ratio=0.05, max_delay=4)
        scfg = dataclasses.replace(
            scfg, compressor=dataclasses.replace(scfg.compressor, topk_impl=impl)
        )
        s_pipe = choose_strategy(
            mesh_pipe2, sasg_enabled=True, pipeline_stages=2,
            trunk_layers=model.pipeline.n_layers,
        )
        built[impl] = build_train_step(model, scfg, mesh_pipe2, s_pipe,
                                       constant(0.05))
    sk = built["kernel"].init(jax.random.PRNGKey(0))
    sr = built["reference"].init(jax.random.PRNGKey(0))
    for batch in _cnn_batches(3):
        sk, mk = built["kernel"].jit_step(sk, batch)
        sr, mr = built["reference"].jit_step(sr, batch)
        assert float(mk["num_sent"]) == float(mr["num_sent"])
        assert max_param_diff(sk, sr) < 1e-6
    assert built["kernel"].bits_wire == built["reference"].bits_wire

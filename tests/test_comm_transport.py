"""repro.comm transport seam: property tests (hypothesis) for the fused
Pallas topk_ef kernel vs the unfused reference, EF candidate-state
commit/discard semantics, layout resolution, and the centralized
(wire-dtype-aware) bit accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.comm import account, build_transport
from repro.core.compressors import CompressorConfig, build_compressor
from repro.core.topk import blocked_topk, _scatter_last
from repro.core.types import tree_size
from repro.kernels.topk_ef.ops import blocked_topk_ef


# ---------------------------------------------------------------------------
# fused kernel == unfused reference (per-shard path)
# ---------------------------------------------------------------------------

@given(
    rows=st.integers(1, 12),
    bc=st.sampled_from([8, 32, 128, 256]),
    kb=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_fused_kernel_equals_unfused_reference(rows, bc, kb, seed):
    """Same payload support, same values, and the exact EF residual
    invariant: densify(payload) + new_err == g + e, bit-for-bit against the
    unfused blocked_topk + scatter-subtract path."""
    kb = min(kb, bc)
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(rows, 3, bc)).astype(np.float32))
    e = jnp.asarray(rng.normal(size=(rows, 3, bc)).astype(np.float32)) * 0.1

    vals_k, idx_k, err_k = blocked_topk_ef(g, e, kb)
    corr = g + e
    p_ref = blocked_topk(corr, kb)
    err_ref = corr - _scatter_last(p_ref.values, p_ref.indices, bc)

    # identical support AND identical values/indices (same tie-break)
    assert np.array_equal(np.asarray(idx_k), np.asarray(p_ref.indices))
    assert np.array_equal(np.asarray(vals_k), np.asarray(p_ref.values))
    assert np.array_equal(np.asarray(err_k), np.asarray(err_ref))
    # exact residual invariant
    dense = _scatter_last(vals_k, idx_k, bc)
    assert np.array_equal(np.asarray(dense + err_k), np.asarray(corr))


@given(
    seed=st.integers(0, 2**16),
    bs=st.sampled_from([16, 64]),
    kfrac=st.floats(0.02, 0.5),
)
@settings(max_examples=15, deadline=None)
def test_transport_kernel_equals_reference_end_to_end(seed, bs, kfrac):
    """Through the full transport encode (layout + compressor): the default
    per-shard kernel path produces bit-identical payloads and candidate EF
    state to topk_impl='reference'."""
    rng = np.random.default_rng(seed)
    g = {
        "w": jnp.asarray(rng.normal(size=(24, 48)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(37,)).astype(np.float32)),
    }
    out = {}
    for impl in ("kernel", "reference"):
        cfg = CompressorConfig(name="topk_ef", k_ratio=kfrac, block_size=bs,
                               topk_impl=impl)
        t = build_transport(cfg, ("data",), 1)
        out[impl] = t.encode(t.init_state(g), g, jax.random.PRNGKey(0))
    (pk, ck), (pr, cr) = out["kernel"], out["reference"]
    for leaf in g:
        assert np.array_equal(np.asarray(pk[leaf].values), np.asarray(pr[leaf].values))
        assert np.array_equal(np.asarray(pk[leaf].indices), np.asarray(pr[leaf].indices))
        assert np.array_equal(np.asarray(ck[leaf]), np.asarray(cr[leaf]))


# ---------------------------------------------------------------------------
# candidate-state commit/discard semantics under send/skip
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**16), impl=st.sampled_from(["kernel", "reference"]))
@settings(max_examples=10, deadline=None)
def test_candidate_state_commit_and_discard(seed, impl):
    """The compressor updates EF state *candidately* (sasg.py commits or
    discards with the send decision):

    - discard (skip): recompressing a new gradient from the UNCHANGED state
      is identical to never having produced the discarded candidate;
    - commit (send): the residual telescopes — densify(p_t) + e_{t+1}
      == g_t + e_t exactly, every committed step.
    """
    rng = np.random.default_rng(seed)
    cfg = CompressorConfig(name="topk_ef", k_ratio=0.1, block_size=16,
                           topk_impl=impl)
    t = build_transport(cfg, ("data",), 1)
    shape = (8, 24)
    g1 = {"w": jnp.asarray(rng.normal(size=shape).astype(np.float32))}
    g2 = {"w": jnp.asarray(rng.normal(size=shape).astype(np.float32))}
    key = jax.random.PRNGKey(0)

    e0 = t.init_state(g1)
    p1, e1_cand = t.encode(e0, g1, key)

    # skip branch: e0 is kept; the candidate leaves no trace
    p2_skip, _ = t.encode(e0, g2, key)
    p2_fresh, _ = t.encode(t.init_state(g1), g2, key)
    assert np.array_equal(np.asarray(p2_skip["w"].values),
                          np.asarray(p2_fresh["w"].values))
    assert np.array_equal(np.asarray(p2_skip["w"].indices),
                          np.asarray(p2_fresh["w"].indices))

    # commit branch: exact telescoping residual invariant
    dense1 = np.asarray(p1["w"].densify()).reshape(shape)
    np.testing.assert_array_equal(
        dense1 + np.asarray(e1_cand["w"]), np.asarray(g1["w"])
    )
    p2, e2_cand = t.encode(e1_cand, g2, key)
    dense2 = np.asarray(p2["w"].densify()).reshape(shape)
    np.testing.assert_allclose(
        dense2 + np.asarray(e2_cand["w"]),
        np.asarray(g2["w"]) + np.asarray(e1_cand["w"]),
        rtol=0, atol=0,
    )


# ---------------------------------------------------------------------------
# layout resolution (legacy spellings) and densify templates
# ---------------------------------------------------------------------------

def test_layout_resolution_legacy_spellings():
    assert CompressorConfig().resolved_layout() == "per_shard"
    assert CompressorConfig().resolved_impl() == "kernel"
    assert CompressorConfig(topk_impl="sharded").resolved_layout() == "per_shard"
    assert CompressorConfig(topk_impl="sharded").resolved_impl() == "reference"
    assert CompressorConfig(topk_impl="exact").resolved_layout() == "per_tensor"
    assert CompressorConfig(topk_impl="block").resolved_impl() == "reference"
    assert CompressorConfig(bucket="global").resolved_layout() == "flat"
    assert CompressorConfig(layout="flat").resolved_layout() == "flat"
    # an explicit layout is never silently overridden by a legacy impl
    # spelling: the conflict errors instead of switching layouts
    explicit = CompressorConfig(layout="per_shard", topk_impl="exact")
    assert explicit.resolved_layout() == "per_shard"
    with pytest.raises(ValueError, match="per_shard layout"):
        build_compressor(explicit)
    assert CompressorConfig(layout="per_tensor",
                            topk_impl="sharded").resolved_layout() == "per_tensor"
    with pytest.raises(ValueError, match="per_shard layout"):
        build_compressor(CompressorConfig(layout="per_shard", topk_impl="bogus"))


def test_densify_uses_gradient_template_not_params():
    """The transport reshapes sparse contributions against the gradient
    template handed to ``densify`` — the stage-sliced-params failure mode the
    old train/step.py guard protected against cannot occur."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(6, 10)).astype(np.float32))}
    cfg = CompressorConfig(name="topk_ef", k_ratio=0.2, layout="per_tensor",
                           topk_impl="exact")
    t = build_transport(cfg, ("data",), 1)
    p, _ = t.encode(t.init_state(g), g, jax.random.PRNGKey(0))
    flat_contrib = {"w": p["w"].densify()}   # what the all-gather mean yields
    upd = t.densify(flat_contrib, g)
    assert upd["w"].shape == g["w"].shape and upd["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# centralized, wire-dtype-aware bit accounting
# ---------------------------------------------------------------------------

def _tree(sizes):
    return {f"l{i}": jnp.zeros(s) for i, s in enumerate(sizes)}


def test_identity_bits_wire_is_dtype_aware():
    """The old accounting hard-coded 32 bits/coord for identity regardless
    of wire_dtype; bits_wire must charge the configured width — and the
    payload must actually carry only that precision (wire emulation)."""
    tree = _tree([(64, 32), (100,)])
    d = tree_size(tree)
    f32 = account(CompressorConfig(name="identity"), tree)
    bf16 = account(CompressorConfig(name="identity", wire_dtype="bfloat16"), tree)
    assert f32.paper == f32.wire == 32.0 * d
    assert bf16.paper == 32.0 * d            # paper convention is fixed
    assert bf16.wire == 16.0 * d
    g = {"w": jnp.full((4,), 1.0 + 2**-10, jnp.float32)}  # not bf16-exact
    tb = build_transport(CompressorConfig(name="identity",
                                          wire_dtype="bfloat16"), ("data",), 1)
    p, _ = tb.encode(tb.init_state(g), g, None)
    assert p["w"].dtype == jnp.float32       # round-tripped for the psum
    np.testing.assert_array_equal(
        np.asarray(p["w"]), np.asarray(g["w"].astype(jnp.bfloat16), np.float32)
    )
    tf = build_transport(CompressorConfig(name="identity"), ("data",), 1)
    pf, _ = tf.encode(tf.init_state(g), g, None)
    np.testing.assert_array_equal(np.asarray(pf["w"]), np.asarray(g["w"]))


def test_qsgd_bits_wire_is_dtype_aware():
    tree = _tree([(64, 32), (100,)])
    d, n_leaves = tree_size(tree), 2
    per_coord = np.log2(256) + 1.0
    f32 = account(CompressorConfig(name="qsgd"), tree)
    bf16 = account(CompressorConfig(name="qsgd", wire_dtype="bfloat16"), tree)
    assert f32.paper == pytest.approx(per_coord * d + 32.0 * n_leaves)
    assert f32.wire == pytest.approx(per_coord * d + 32.0 * n_leaves)
    # quantized coordinates keep their encoded width; the per-leaf norm
    # scalar is a wire value and pays wire_dtype
    assert bf16.wire == pytest.approx(per_coord * d + 16.0 * n_leaves)
    assert bf16.paper == f32.paper


def test_dense_scalar_overheads_dtype_aware():
    tree = _tree([(32, 8)])
    d = tree_size(tree)
    sg = account(CompressorConfig(name="signsgd_ef", wire_dtype="bfloat16"), tree)
    tg = account(CompressorConfig(name="terngrad", wire_dtype="bfloat16"), tree)
    assert sg.wire == pytest.approx(1.0 * d + 16.0)
    assert tg.wire == pytest.approx(np.log2(3.0) * d + 16.0)


def test_topk_wire_bits_value_dtype_and_indices():
    tree = _tree([(8, 128)])
    base = CompressorConfig(name="topk_ef", k_ratio=0.1, block_size=64,
                            topk_impl="reference")
    r32 = account(base, tree)
    rbf = account(dataclasses.replace(base, wire_dtype="bfloat16"), tree)
    rcp = account(dataclasses.replace(base, wire_dtype="bfloat16",
                                      compact_indices=True), tree)
    k = r32.buckets[0].k
    assert r32.wire == pytest.approx((32 + 32) * k)
    assert rbf.wire == pytest.approx((16 + 32) * k)
    assert rcp.wire == pytest.approx((16 + 8) * k)   # block 64 -> u8 indices
    assert r32.paper == rbf.paper == rcp.paper == pytest.approx(32 * k)


def test_per_layer_k_ratio_schedule_reported_per_bucket():
    """Shi et al.-style layer-wise k ratios: applied by the compressor and
    visible in the transport's per-bucket report."""
    tree = {"dense": jnp.zeros((64, 64)), "head": jnp.zeros((64, 64))}
    cfg = CompressorConfig(
        name="topk_ef", k_ratio=0.01, block_size=64, topk_impl="reference",
        k_ratio_per_layer=(("head", 0.25),),
    )
    rep = account(cfg, tree)
    rows = {r["bucket"]: r for r in rep.rows()}
    assert rows["head"]["k"] == 1024 and rows["head"]["k_ratio"] == 0.25
    assert rows["dense"]["k"] < rows["head"]["k"]
    # the schedule drives the actual payload, not just the report
    t = build_transport(cfg, ("data",), 1)
    rng = np.random.default_rng(1)
    g = {k: jnp.asarray(rng.normal(size=v.shape).astype(np.float32))
         for k, v in tree.items()}
    p, _ = t.encode(t.init_state(g), g, jax.random.PRNGKey(0))
    assert p["head"].values.size == rows["head"]["k"]
    assert p["dense"].values.size == rows["dense"]["k"]


def test_flat_layout_ignores_k_schedule():
    """The flat layout's single "__global__" pseudo-leaf is not a layer: the
    layer-wise schedule must not match it (even with a pattern that is a
    substring of "__global__"), and payload size must agree with the
    accounting."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(50, 40)).astype(np.float32))}
    cfg = CompressorConfig(
        name="topk_ef", k_ratio=0.01, layout="flat", topk_impl="exact",
        k_ratio_per_layer=(("glob", 0.25),),
    )
    rep = account(cfg, g)
    assert rep.buckets[0].k == 20                    # 1% of 2000, not 25%
    t = build_transport(cfg, ("data",), 1)
    p, _ = t.encode(t.init_state(g), g, jax.random.PRNGKey(0))
    assert p["__global__"].values.size == rep.buckets[0].k
    assert rep.paper == 32.0 * rep.buckets[0].k


def test_transport_bits_match_report_totals():
    tree = _tree([(16, 32), (50,)])
    for name in ("topk_ef", "randk", "identity", "qsgd", "signsgd_ef", "terngrad"):
        cfg = CompressorConfig(name=name, k_ratio=0.1)
        t = build_transport(cfg, ("data",), 1)
        rep = t.bits_report(tree)
        assert t.bits_paper(tree) == rep.paper
        assert t.bits_wire(tree) == rep.wire
        assert rep.paper > 0 and rep.wire > 0

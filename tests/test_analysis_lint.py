"""Per-rule positive/negative snippets for the repro.analysis lint pass,
fingerprint/baseline semantics, and report determinism."""
import json
import textwrap

from repro.analysis.findings import (
    load_baseline,
    split_by_baseline,
    write_baseline,
)
from repro.analysis.lint import lint_source, report_rows, run_lint
from repro.analysis.rules.registry import check_registry_consistency

CORE = "repro/core/_snippet.py"      # inside every rule's scope


def _lint(src, path=CORE, rule=None):
    findings = lint_source(textwrap.dedent(src), path=path)
    return [f for f in findings if rule is None or f.rule == rule]


# ---------------------------------------------------------------------------
# axis-name
# ---------------------------------------------------------------------------

def test_axis_name_flags_string_literal():
    fs = _lint("""
        import jax
        def f(x):
            return jax.lax.psum(x, "data")
    """, rule="axis-name")
    assert len(fs) == 1 and "hardcoded axis name" in fs[0].message


def test_axis_name_flags_kwarg_and_queries():
    fs = _lint("""
        import jax
        def f(x):
            a = jax.lax.all_gather(x, axis_name="stage", tiled=True)
            i = jax.lax.axis_index("data")
            return a, i
    """, rule="axis-name")
    assert len(fs) == 2


def test_axis_name_allows_bound_axis_and_param_default():
    fs = _lint("""
        import jax
        def f(x, axis="stage"):
            return jax.lax.psum(x, axis) + jax.lax.axis_index(axis)
    """, rule="axis-name")
    assert fs == []


# ---------------------------------------------------------------------------
# tracer-leak
# ---------------------------------------------------------------------------

def test_tracer_leak_flags_item_float_branch_and_np():
    fs = _lint("""
        import jax, jax.numpy as jnp, numpy as np
        def f(x):
            a = x.item()
            b = float(jnp.sum(x))
            if jnp.any(x > 0):
                x = x + 1
            c = np.sum(x)
            return a, b, c
    """, rule="tracer-leak")
    assert len(fs) == 4
    msgs = " ".join(f.message for f in fs)
    assert ".item()" in msgs and "concretizes" in msgs
    assert "branch" in msgs and "np.sum" in msgs


def test_tracer_leak_allows_static_shape_code():
    fs = _lint("""
        import jax.numpy as jnp, numpy as np
        def f(x):
            if x.ndim > 2:
                x = x.reshape(-1)
            n = int(np.prod(x.shape))
            return jnp.zeros((n,), x.dtype)
    """, rule="tracer-leak")
    assert fs == []


def test_tracer_leak_scoped_to_traced_modules():
    src = """
        import jax.numpy as jnp
        def f(x):
            return float(jnp.sum(x))
    """
    assert _lint(src, path="repro/core/x.py", rule="tracer-leak")
    # launch / configs drivers run host-side by design
    assert _lint(src, path="repro/launch/x.py", rule="tracer-leak") == []


def test_tracer_leak_ignores_module_level_numpy():
    fs = _lint("""
        import numpy as np
        TABLE = np.sum([[1, 2], [3, 4]], axis=0)
    """, rule="tracer-leak")
    assert fs == []


# ---------------------------------------------------------------------------
# dsize-collective
# ---------------------------------------------------------------------------

def test_dsize_flags_raw_collective_outside_seam():
    fs = _lint("""
        import jax
        def f(g, axis):
            return jax.lax.psum(g, axis)
    """, rule="dsize-collective")
    assert len(fs) == 1 and "Transport seam" in fs[0].message


def test_dsize_allows_queries_literals_and_the_seam():
    src = """
        import jax
        def f(x, axis):
            s = jax.lax.psum(1, axis)
            i = jax.lax.axis_index(axis)
            return s, i
    """
    assert _lint(src, rule="dsize-collective") == []
    # the seam itself is exempt: collectives are its job
    dsized = """
        import jax
        def f(g, axis):
            return jax.lax.pmean(g, axis)
    """
    assert _lint(dsized, path="repro/comm/x.py", rule="dsize-collective") == []
    assert _lint(dsized, path="repro/core/x.py", rule="dsize-collective")


def test_pragma_suppresses_single_site():
    fs = _lint("""
        import jax
        def f(g, axis):
            return jax.lax.psum(g, axis)  # repro-lint: ignore[dsize-collective]
    """, rule="dsize-collective")
    assert fs == []


# ---------------------------------------------------------------------------
# fingerprints + baseline
# ---------------------------------------------------------------------------

def test_fingerprint_survives_line_moves():
    src = """
        import jax
        def f(g, axis):
            return jax.lax.psum(g, axis)
    """
    f1 = _lint(src, rule="dsize-collective")[0]
    f2 = _lint("\n\n\n" + textwrap.dedent(src), rule="dsize-collective")[0]
    assert f1.line != f2.line
    assert f1.fingerprint == f2.fingerprint


def test_identical_snippets_get_distinct_fingerprints():
    fs = _lint("""
        import jax
        def f(g, axis):
            a = jax.lax.psum(g, axis)
            b = jax.lax.psum(g, axis)
            return a, b
    """, rule="dsize-collective")
    assert len(fs) == 2
    assert fs[0].fingerprint != fs[1].fingerprint
    assert {f.occurrence for f in fs} == {0, 1}


def test_baseline_roundtrip(tmp_path):
    fs = _lint("""
        import jax
        def f(g, axis):
            return jax.lax.psum(g, axis)
    """, rule="dsize-collective")
    path = str(tmp_path / "baseline.json")
    write_baseline(fs, justifications={fs[0].fingerprint: "test reason"},
                   path=path)
    bl = load_baseline(path)
    new, accepted = split_by_baseline(fs, bl)
    assert new == [] and accepted == fs
    assert bl.entries[fs[0].fingerprint]["justification"] == "test reason"
    assert bl.stale([]) == [fs[0].fingerprint]


def test_repo_sweep_is_clean_against_committed_baseline():
    findings = run_lint()
    bl = load_baseline()
    new, _accepted = split_by_baseline(findings, bl)
    assert new == [], "un-baselined lint findings:\n" + "\n".join(map(str, new))
    assert bl.stale(findings) == []


def test_injected_dsize_collective_is_not_baselined():
    # the gate the ISSUE demands: a fresh d-sized collective anywhere in
    # linted code must surface as a NEW finding against the committed baseline
    fs = _lint("""
        import jax
        def rogue(update, axis):
            return jax.lax.pmean(update, axis)
    """, path="repro/train/_rogue.py", rule="dsize-collective")
    assert len(fs) == 1
    bl = load_baseline()
    new, _ = split_by_baseline(fs, bl)
    assert new == fs


# ---------------------------------------------------------------------------
# registry-consistency
# ---------------------------------------------------------------------------

def test_registry_default_is_consistent():
    assert check_registry_consistency() == []


def test_registry_detects_unaccounted_compressor():
    fs = check_registry_consistency({"mystery_codec": object()})
    assert fs and any("mystery_codec" in f.snippet for f in fs)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_lint_report_is_deterministic():
    a = run_lint()
    b = run_lint()
    ra = json.dumps({"findings": report_rows(a)}, indent=1, sort_keys=True)
    rb = json.dumps({"findings": report_rows(b)}, indent=1, sort_keys=True)
    assert ra == rb

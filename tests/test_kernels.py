"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py
pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_topk.block_topk import block_topk_pallas
from repro.kernels.block_topk.ref import block_topk_ref
from repro.kernels.topk_ef.ref import topk_ef_ref
from repro.kernels.topk_ef.topk_ef import topk_ef_pallas
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.models.ssd import ssd_chunked as ssd_ref


@pytest.mark.parametrize("nb,bs", [(8, 128), (16, 256), (4, 512), (32, 64)])
@pytest.mark.parametrize("kb", [1, 3, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_topk_kernel_sweep(nb, bs, kb, dtype):
    rng = np.random.default_rng(nb * bs + kb)
    x = jnp.asarray(rng.normal(size=(nb, bs)), dtype).astype(jnp.float32)
    v_k, i_k = block_topk_pallas(x, kb, interpret=True)
    v_r, i_r = block_topk_ref(x, kb)
    # same selected SET per row (tie order may differ): compare sorted |values|
    np.testing.assert_allclose(
        np.sort(np.abs(np.asarray(v_k)), -1),
        np.sort(np.abs(np.asarray(v_r)), -1),
        rtol=1e-6, atol=1e-6,
    )
    # kernel indices must point at the values it claims
    got = np.take_along_axis(np.asarray(x), np.asarray(i_k), axis=1)
    np.testing.assert_allclose(got, np.asarray(v_k), rtol=1e-6)


@pytest.mark.parametrize("nb,bs,kb", [(8, 128, 2), (16, 256, 5), (4, 64, 1)])
@pytest.mark.parametrize("lr", [1.0, 0.05])
def test_topk_ef_kernel_sweep(nb, bs, kb, lr):
    rng = np.random.default_rng(nb + bs + kb)
    g = jnp.asarray(rng.normal(size=(nb, bs)).astype(np.float32))
    e = jnp.asarray(rng.normal(size=(nb, bs)).astype(np.float32)) * 0.1
    ne_k, v_k, i_k = topk_ef_pallas(g, e, jnp.float32(lr), kb, interpret=True)
    ne_r, v_r, i_r = topk_ef_ref(g, e, lr, kb)
    np.testing.assert_allclose(
        np.sort(np.abs(np.asarray(v_k)), -1),
        np.sort(np.abs(np.asarray(v_r)), -1),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(np.asarray(ne_k), np.asarray(ne_r), rtol=1e-5, atol=1e-6)
    # fusion invariant: selected + residual == lr*g + e exactly
    corrected = lr * np.asarray(g) + np.asarray(e)
    dense = np.zeros_like(corrected)
    np.put_along_axis(dense, np.asarray(i_k), np.asarray(v_k), axis=1)
    np.testing.assert_allclose(dense + np.asarray(ne_k), corrected, rtol=1e-5, atol=1e-6)


def test_topk_ef_ops_payload_roundtrip():
    from repro.kernels.topk_ef.ops import topk_ef

    rng = np.random.default_rng(3)
    d = 1000  # non-multiple of block: exercises padding
    g = jnp.asarray(rng.normal(size=d).astype(np.float32))
    e = jnp.zeros((d,), jnp.float32)
    p, ne = topk_ef(g, e, jnp.float32(1.0), k=50, block_size=128)
    assert int(p.indices.max()) < d
    np.testing.assert_allclose(
        np.asarray(p.densify() + ne), np.asarray(g), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 16, 1, 16, 32),
    (1, 64, 2, 8, 2, 8, 16),
    (2, 96, 6, 8, 3, 4, 32),
])
def test_ssd_kernel_vs_oracle(b, s, h, p, g, n, chunk):
    rng = np.random.default_rng(b + s + h)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.05, 0.5, size=(b, s, h)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(h,)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32)) * 0.3
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32)) * 0.3
    y_k, h_k = ssd_ops.ssd_chunked(x, dt, a_log, bm, cm, chunk)
    y_r, h_r = ssd_ref(x, dt, a_log, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_with_initial_state():
    rng = np.random.default_rng(9)
    b, s, h, p, g, n, chunk = 1, 64, 2, 8, 1, 8, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.05, 0.5, size=(b, s, h)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(h,)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32)) * 0.3
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(np.float32)) * 0.3
    h0 = jnp.asarray(rng.normal(size=(b, h, p, n)).astype(np.float32)) * 0.1
    y_k, hf_k = ssd_ops.ssd_chunked(x, dt, a_log, bm, cm, chunk, h0)
    y_r, hf_r = ssd_ref(x, dt, a_log, bm, cm, chunk, h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf_k), np.asarray(hf_r), rtol=2e-4, atol=2e-4)

"""Hierarchical x pipeline composition (ROADMAP open item): on a 16-device
(pod, data, stage, model) mesh, the pipelined hierarchical SASG step must
reproduce the non-pipelined hierarchical run — same send/skip decisions,
same bits counters, params to the top-k tie-flip tolerance (the same
equality tiers as tests/test_pipeline_sasg.py).

Runs in a SUBPROCESS because the 16 fake CPU devices must be forced before
jax imports (conftest pins the session to 8), and is marked slow (two
multi-minute XLA compiles on the 4-axis mesh).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
import repro.compat
from repro.configs import get_config
from repro.core import sasg_config
from repro.dist.strategy import choose_strategy
from repro.models import build
from repro.optim import constant
from repro.train import build_train_step

model = build(dataclasses.replace(get_config("cnn_cifar"), d_model=16))
scfg = sasg_config(k_ratio=0.05, max_delay=4)

mesh_flat = repro.compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
mesh_pipe = repro.compat.make_mesh((2, 2, 2, 2), ("pod", "data", "stage", "model"))

s_flat = choose_strategy(mesh_flat, sasg_enabled=True)
s_pipe = choose_strategy(mesh_pipe, sasg_enabled=True, pipeline_stages=2,
                         trunk_layers=model.pipeline.n_layers)
assert s_flat.name == s_pipe.name == "hierarchical", (s_flat.name, s_pipe.name)
assert s_pipe.pipelined and s_pipe.pipeline_stages == 2
assert s_flat.num_workers == s_pipe.num_workers == 2

bf = build_train_step(model, scfg, mesh_flat, s_flat, constant(0.05))
bp = build_train_step(model, scfg, mesh_pipe, s_pipe, constant(0.05))
assert bf.bits_wire == bp.bits_wire and bf.bits_paper == bp.bits_paper

sf, sp = bf.init(jax.random.PRNGKey(0)), bp.init(jax.random.PRNGKey(0))

def max_diff(sa, sb):
    return max(
        float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params))
    )

assert max_diff(sf, sp) == 0.0
rng = np.random.default_rng(0)
for _ in range(3):
    batch = {
        "x": jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32)),
    }
    sf, mf = bf.jit_step(sf, batch)
    sp, mp = bp.jit_step(sp, batch)
    assert float(mf["num_sent"]) == float(mp["num_sent"]), "send decisions diverged"
    d = max_diff(sf, sp)
    assert d < 2e-2, f"params diverged: {d}"
assert float(sf.counters.rounds) == float(sp.counters.rounds)
np.testing.assert_allclose(float(sf.counters.bits_wire),
                           float(sp.counters.bits_wire), rtol=1e-6)
print("HIER_PIPE_OK")
"""


@pytest.mark.slow
def test_hierarchical_pipeline_matches_flat_hierarchical():
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SCRIPT)],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert p.returncode == 0 and "HIER_PIPE_OK" in p.stdout, (
        f"stdout:\n{p.stdout[-4000:]}\nstderr:\n{p.stderr[-4000:]}"
    )

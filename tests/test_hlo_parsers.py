"""Canned-HLO fixtures for the text-level analyzers.

Covers repro.launch.hlo_analysis (flat collective parser + replica-group /
source-target parsing + ring wire factors) and repro.launch.hlo_cost (the
while-loop-aware analyzer: trip-count recovery and weighted aggregation).
"""
import numpy as np
import pytest

from repro.launch import hlo_analysis as H
from repro.launch import hlo_cost as HC

FLAT_HLO = """\
HloModule canned

ENTRY %main (p0: f32[1024]) -> f32[256] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096]{0} all-gather(%p0), replica_groups=[1,4]<=[4], dimensions={0}
  %cp = f32[1024]{0} collective-permute(%p0), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  ROOT %rs = f32[256]{0} reduce-scatter(%ar), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
}
"""


def test_collect_collectives_counts_and_result_bytes():
    stats = H.collect_collectives(FLAT_HLO)
    assert stats.counts == {
        "all-reduce": 1, "all-gather": 1, "collective-permute": 1,
        "reduce-scatter": 1,
    }
    assert stats.result_bytes["all-reduce"] == 4096
    assert stats.result_bytes["all-gather"] == 16384
    assert stats.result_bytes["collective-permute"] == 1024 * 4
    assert stats.result_bytes["reduce-scatter"] == 1024


def test_collect_collectives_ring_wire_factors():
    stats = H.collect_collectives(FLAT_HLO)
    # g=4 groups: all-reduce 2(g-1)/g, all-gather (g-1)/g, reduce-scatter
    # (g-1)x shard, permute 1x payload
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * 3 / 4 * 4096)
    assert stats.wire_bytes["all-gather"] == pytest.approx(3 / 4 * 16384)
    assert stats.wire_bytes["reduce-scatter"] == pytest.approx(3 * 1024)
    assert stats.wire_bytes["collective-permute"] == pytest.approx(4096)
    assert stats.total_wire == pytest.approx(sum(stats.wire_bytes.values()))


def test_wire_factor_table():
    assert H.wire_factor("all-reduce", 2) == pytest.approx(1.0)
    assert H.wire_factor("all-gather", 2) == pytest.approx(0.5)
    assert H.wire_factor("reduce-scatter", 4) == pytest.approx(3.0)
    assert H.wire_factor("collective-permute", 2) == pytest.approx(1.0)
    # degenerate single-device group moves nothing (permute excepted)
    assert H.wire_factor("all-reduce", 1) == 0.0


def test_parse_replica_groups_list_form():
    line = "  %x = f32[8]{0} all-reduce(%y), replica_groups={{0,2},{1,3}}, to_apply=%add"
    assert H.parse_replica_groups(line) == [[0, 2], [1, 3]]


def test_parse_replica_groups_iota_forms():
    assert H.parse_replica_groups("replica_groups=[2,2]<=[4]") == [[0, 1], [2, 3]]
    # transpose form: iota(4).reshape(2,2).T -> groups {0,2},{1,3}
    assert H.parse_replica_groups(
        "replica_groups=[2,2]<=[2,2]T(1,0)"
    ) == [[0, 2], [1, 3]]
    assert H.parse_replica_groups("no groups here") is None


def test_parse_source_target_pairs():
    line = "collective-permute(%p), source_target_pairs={{0,1},{1,0},{2,3},{3,2}}"
    assert H.parse_source_target_pairs(line) == [(0, 1), (1, 0), (2, 3), (3, 2)]
    assert H.parse_source_target_pairs("all-reduce(%p)") is None


# ---------------------------------------------------------------------------
# mesh-axis attribution (repro.analysis.hlo_audit)
# ---------------------------------------------------------------------------

class _Dev:
    def __init__(self, i):
        self.id = i


class _Mesh:
    """Duck-typed mesh: logical device array + axis names."""

    def __init__(self, shape, names):
        n = int(np.prod(shape))
        self.axis_names = tuple(names)
        self.devices = np.array(
            [_Dev(i) for i in range(n)], dtype=object
        ).reshape(shape)


def test_classify_axes_on_2x2_mesh():
    from repro.analysis.hlo_audit import classify_axes

    mesh = _Mesh((2, 2), ("data", "stage"))
    # id = 2*data + stage
    assert classify_axes(mesh, [[0, 2], [1, 3]]) == ("data",)
    assert classify_axes(mesh, [[0, 1], [2, 3]]) == ("stage",)
    assert classify_axes(mesh, [[0, 1, 2, 3]]) == ("data", "stage")
    # default group (no replica_groups attribute) spans the whole mesh
    assert classify_axes(mesh, None) == ("data", "stage")
    # permute pairs along the stage axis
    assert classify_axes(mesh, None, pairs=[(0, 1), (1, 0), (2, 3), (3, 2)]) \
        == ("stage",)


def test_parse_collective_ops_attributes_axes():
    from repro.analysis.hlo_audit import parse_collective_ops

    mesh = _Mesh((2, 2), ("data", "stage"))
    hlo = """\
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %a = f32[64]{0} all-reduce(%p0), replica_groups={{0,2},{1,3}}, to_apply=%add
  ROOT %b = f32[64]{0} collective-permute(%a), source_target_pairs={{0,1},{1,0},{2,3},{3,2}}
}
"""
    ops = parse_collective_ops(hlo, mesh)
    assert [(o.kind, o.axes) for o in ops] == [
        ("all-reduce", ("data",)),
        ("collective-permute", ("stage",)),
    ]
    assert ops[0].wire_bytes == pytest.approx(256)   # 2*(1/2)*256
    assert ops[1].wire_bytes == pytest.approx(256)


# ---------------------------------------------------------------------------
# hlo_cost: while-loop trip counts
# ---------------------------------------------------------------------------

LOOP_HLO = """\
HloModule loop

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %d = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%d), replica_groups={{0,1}}, to_apply=%add
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(13)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,128]) -> (s32[], f32[128,128]) {
  %x = f32[128,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(%c0, %x)
  ROOT %w = (s32[], f32[128,128]) while(%init), condition=%cond, body=%body
}
"""


def test_hlo_cost_weights_loop_body_by_trip_count():
    cost = HC.analyze(LOOP_HLO)
    # dot: 2 * 128^3 flops per iteration, 13 iterations
    assert cost.flops == pytest.approx(13 * 2 * 128 ** 3)
    # all-reduce over g=2: wire = 2*(1/2)*64KiB per iteration
    assert cost.coll_result["all-reduce"] == pytest.approx(13 * 128 * 128 * 4)
    assert cost.coll_wire["all-reduce"] == pytest.approx(13 * 128 * 128 * 4)


def test_hlo_cost_without_loop_counts_once():
    cost = HC.analyze(FLAT_HLO)
    assert cost.flops == 0.0
    assert cost.coll_result["all-reduce"] == pytest.approx(4096)
    assert cost.coll_wire["reduce-scatter"] == pytest.approx(3 * 1024)


def test_hlo_cost_parse_computations_finds_entry():
    comps = HC.parse_computations(LOOP_HLO)
    assert {"add", "body", "cond", "main"} <= set(comps)
    assert comps["__entry__"] is comps["main"]
    opcodes = {i.opcode for i in comps["body"]}
    assert {"dot", "all-reduce", "get-tuple-element"} <= opcodes


def test_hlo_cost_shape_map_resolves_dot_operands():
    comps = HC.parse_computations(LOOP_HLO)
    shapes = HC.build_shape_map(comps)
    assert shapes["x"] == (128, 128)
    assert shapes["d"] == (128, 128)

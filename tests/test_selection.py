"""Unit tests of the adaptive selection rule (paper eq. 6)."""
import jax.numpy as jnp
import numpy as np

from repro.core.selection import (
    SelectionConfig,
    SelectionState,
    advance_tau,
    init_selection,
    push_window,
    should_send,
)


def _state(tau=1, window=None, D=4):
    return SelectionState(
        tau=jnp.asarray(tau, jnp.int32),
        window=jnp.asarray(window if window is not None else np.zeros(D), jnp.float32),
    )


def test_send_when_difference_large():
    cfg = SelectionConfig(max_delay=4)
    g_new = {"w": jnp.ones(8)}
    g_stale = {"w": jnp.zeros(8)}
    st = _state(window=[0.001] * 4)
    alphas = jnp.ones(4)
    assert bool(should_send(cfg, g_new, g_stale, st, alphas, num_workers=4))


def test_skip_when_difference_small():
    cfg = SelectionConfig(max_delay=4)
    g = {"w": jnp.ones(8)}
    st = _state(window=[100.0] * 4)
    alphas = jnp.ones(4)
    assert not bool(should_send(cfg, g, g, st, alphas, num_workers=4))


def test_staleness_cap_forces_send():
    cfg = SelectionConfig(max_delay=4)
    g = {"w": jnp.ones(8)}
    st = _state(tau=4, window=[100.0] * 4)
    assert bool(should_send(cfg, g, g, st, jnp.ones(4), num_workers=4))


def test_deadline_skip_override():
    """Straggler mitigation: force_skip pushes the worker into M_c unless the
    staleness cap fires."""
    cfg = SelectionConfig(max_delay=4, deadline_skip=True)
    g_new = {"w": jnp.ones(8)}
    g_stale = {"w": jnp.zeros(8)}
    st = _state(tau=1, window=[0.0] * 4)
    send = should_send(cfg, g_new, g_stale, st, jnp.ones(4), 4,
                       force_skip=jnp.asarray(True))
    assert not bool(send)
    st_capped = _state(tau=4, window=[0.0] * 4)
    send = should_send(cfg, g_new, g_stale, st_capped, jnp.ones(4), 4,
                       force_skip=jnp.asarray(True))
    assert bool(send)


def test_tau_and_window_updates():
    st = _state(tau=2, window=[1.0, 2.0, 3.0, 4.0])
    assert int(advance_tau(st, jnp.asarray(True))) == 1
    assert int(advance_tau(st, jnp.asarray(False))) == 3
    w = push_window(st, jnp.asarray(9.0))
    np.testing.assert_allclose(np.asarray(w), [9.0, 1.0, 2.0, 3.0])


def test_m_squared_scaling():
    """rhs scales as 1/M^2 (paper eq. 6): more workers -> stricter skipping."""
    cfg = SelectionConfig(max_delay=2)
    g_new = {"w": jnp.full(8, 0.1)}
    g_stale = {"w": jnp.zeros(8)}
    st = _state(window=[10.0, 10.0], D=2)
    a = jnp.ones(2)
    send_small_m = bool(should_send(cfg, g_new, g_stale, st, a, num_workers=2))
    send_large_m = bool(should_send(cfg, g_new, g_stale, st, a, num_workers=64))
    assert (not send_small_m) and send_large_m

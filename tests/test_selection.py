"""Unit + property tests of the adaptive selection rule (paper eq. 6).

The edge cases (threshold boundary, staleness saturation, window rotation)
run as hypothesis properties against a plain-numpy mirror of the rule; the
suite works identically under real hypothesis and under the deterministic
stub in tests/_hypothesis_stub.py (conftest installs it when the package is
absent), so it stays meaningful on the no-deps test image.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.selection import (
    SelectionConfig,
    SelectionState,
    advance_tau,
    push_window,
    should_send,
)


def _state(tau=1, window=None, D=4):
    return SelectionState(
        tau=jnp.asarray(tau, jnp.int32),
        window=jnp.asarray(window if window is not None else np.zeros(D), jnp.float32),
    )


def test_send_when_difference_large():
    cfg = SelectionConfig(max_delay=4)
    g_new = {"w": jnp.ones(8)}
    g_stale = {"w": jnp.zeros(8)}
    st = _state(window=[0.001] * 4)
    alphas = jnp.ones(4)
    assert bool(should_send(cfg, g_new, g_stale, st, alphas, num_workers=4))


def test_skip_when_difference_small():
    cfg = SelectionConfig(max_delay=4)
    g = {"w": jnp.ones(8)}
    st = _state(window=[100.0] * 4)
    alphas = jnp.ones(4)
    assert not bool(should_send(cfg, g, g, st, alphas, num_workers=4))


# ---------------------------------------------------------------------------
# properties (run under real hypothesis or the deterministic stub)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    diff=st.floats(min_value=0.0, max_value=4.0),
    win=st.floats(min_value=0.0, max_value=50.0),
    tau=st.integers(min_value=1, max_value=4),
    workers=st.sampled_from([1, 2, 8, 64]),
)
def test_rule_matches_numpy_mirror(diff, win, tau, workers):
    """should_send == the strict-inequality numpy mirror of eq. (6), with the
    staleness cap as the only override — for any lhs/rhs configuration,
    including the lhs == rhs boundary (diff=0, win=0 -> skip unless capped)."""
    D = 4
    cfg = SelectionConfig(max_delay=D)
    g_stale = {"w": jnp.zeros(8)}
    g_new = {"w": jnp.full(8, diff, jnp.float32)}
    state = _state(tau=tau, window=[win] * D, D=D)
    alphas = jnp.ones(D)
    got = bool(should_send(cfg, g_new, g_stale, state, alphas, workers))
    lhs = np.float32(8) * np.float32(diff) ** 2
    rhs = np.float32(D) * np.float32(win) / np.float32(workers) ** 2
    want = bool(lhs > rhs) or tau >= D
    assert got == want, (lhs, rhs, tau)


@settings(max_examples=25, deadline=None)
@given(win=st.floats(min_value=0.0, max_value=10.0))
def test_threshold_boundary_skips(win):
    """Exactly on the boundary (lhs == rhs) the rule must SKIP: eq. (6) is a
    strict inequality, so a worker whose gradient change only matches the
    parameter-drift bound reuses its stale payload."""
    D = 2
    cfg = SelectionConfig(max_delay=D)
    # build both sides from the SAME f32 square so lhs == rhs bitwise
    w32 = np.float32(win)
    sq = np.float32(w32 * w32)
    g_new = {"w": jnp.asarray([w32], jnp.float32)}
    g_stale = {"w": jnp.zeros(1, jnp.float32)}
    state = _state(tau=1, window=[float(sq), 0.0], D=D)
    alphas = jnp.ones(D)
    assert not bool(should_send(cfg, g_new, g_stale, state, alphas, 1))


@settings(max_examples=40, deadline=None)
@given(
    tau=st.integers(min_value=1, max_value=6),
    send=st.sampled_from([True, False]),
)
def test_advance_tau_step(tau, send):
    """advance_tau resets to 1 on a send and increments by one on a skip."""
    state = _state(tau=tau, window=[0.0] * 4)
    out = int(advance_tau(state, jnp.asarray(send)))
    assert out == (1 if send else tau + 1)


@settings(max_examples=30, deadline=None)
@given(
    win=st.floats(min_value=0.0, max_value=1e6),
    steps=st.integers(min_value=1, max_value=12),
)
def test_tau_saturates_at_tau_max(win, steps):
    """Driving the rule repeatedly keeps tau in [1, D]: however large the
    window (rhs) is, the cap forces an upload before tau exceeds D — the
    bounded-staleness guarantee Theorem 1's D-delay analysis needs."""
    D = 4
    cfg = SelectionConfig(max_delay=D)
    g = {"w": jnp.ones(4)}  # fresh == stale: rule alone would always skip
    alphas = jnp.ones(D)
    tau = 1
    for _ in range(steps):
        state = _state(tau=tau, window=[win] * D, D=D)
        send = should_send(cfg, g, g, state, alphas, num_workers=2)
        tau = int(advance_tau(state, send))
        assert 1 <= tau <= D
        if tau == D:
            assert bool(
                should_send(cfg, g, g, _state(tau=tau, window=[win] * D, D=D),
                            alphas, num_workers=2)
            )


@settings(max_examples=30, deadline=None)
@given(
    v=st.floats(min_value=0.0, max_value=1e9),
    d=st.integers(min_value=1, max_value=10),
)
def test_push_window_rotation(v, d):
    """push_window shifts the newest ||w^{t+1}-w^t||^2 in at d=1, drops the
    oldest entry, and preserves length and dtype."""
    old = np.arange(1, d + 1, dtype=np.float32)
    state = SelectionState(tau=jnp.ones((), jnp.int32), window=jnp.asarray(old))
    new = np.asarray(push_window(state, jnp.asarray(v, jnp.float32)))
    assert new.shape == (d,) and new.dtype == np.float32
    np.testing.assert_allclose(new[0], np.float32(v))
    np.testing.assert_allclose(new[1:], old[:-1])


def test_staleness_cap_forces_send():
    cfg = SelectionConfig(max_delay=4)
    g = {"w": jnp.ones(8)}
    st_capped = _state(tau=4, window=[100.0] * 4)
    assert bool(should_send(cfg, g, g, st_capped, jnp.ones(4), num_workers=4))


def test_deadline_skip_override():
    """Straggler mitigation: force_skip pushes the worker into M_c unless the
    staleness cap fires."""
    cfg = SelectionConfig(max_delay=4, deadline_skip=True)
    g_new = {"w": jnp.ones(8)}
    g_stale = {"w": jnp.zeros(8)}
    st = _state(tau=1, window=[0.0] * 4)
    send = should_send(cfg, g_new, g_stale, st, jnp.ones(4), 4,
                       force_skip=jnp.asarray(True))
    assert not bool(send)
    st_capped = _state(tau=4, window=[0.0] * 4)
    send = should_send(cfg, g_new, g_stale, st_capped, jnp.ones(4), 4,
                       force_skip=jnp.asarray(True))
    assert bool(send)


def test_tau_and_window_updates():
    st = _state(tau=2, window=[1.0, 2.0, 3.0, 4.0])
    assert int(advance_tau(st, jnp.asarray(True))) == 1
    assert int(advance_tau(st, jnp.asarray(False))) == 3
    w = push_window(st, jnp.asarray(9.0))
    np.testing.assert_allclose(np.asarray(w), [9.0, 1.0, 2.0, 3.0])


def test_m_squared_scaling():
    """rhs scales as 1/M^2 (paper eq. 6): more workers -> stricter skipping."""
    cfg = SelectionConfig(max_delay=2)
    g_new = {"w": jnp.full(8, 0.1)}
    g_stale = {"w": jnp.zeros(8)}
    st = _state(window=[10.0, 10.0], D=2)
    a = jnp.ones(2)
    send_small_m = bool(should_send(cfg, g_new, g_stale, st, a, num_workers=2))
    send_large_m = bool(should_send(cfg, g_new, g_stale, st, a, num_workers=64))
    assert (not send_small_m) and send_large_m

"""choose_strategy edge cases: 1-D meshes, SASG off, replication threshold."""
import pytest

from repro import compat
from repro.dist.strategy import (
    REPLICA_OVERHEAD,
    Strategy,
    choose_strategy,
    worker_replication_fits,
)


def test_flat_on_2d_mesh(mesh2d):
    s = choose_strategy(mesh2d, sasg_enabled=True)
    assert s.name == "flat"
    assert s.uses_shard_map
    assert s.upload_axes == ("data",) and s.grad_axes == ("data",)
    assert s.fsdp_axis is None and s.inner_dp is None
    assert s.tp_axis == "model" and s.num_workers == 4


def test_hierarchical_on_3d_mesh(mesh3d):
    s = choose_strategy(mesh3d, sasg_enabled=True)
    assert s.name == "hierarchical"
    assert s.upload_axes == ("pod",) and s.grad_axes == ("pod", "data")
    # TP-only workaround: FSDP inside the manual pod region is a known
    # XLA SPMD partitioner limit (tests/test_known_limits.py)
    assert s.fsdp_axis is None
    assert s.inner_dp == "data" and s.num_workers == 2


def test_1d_mesh_no_model_axis():
    mesh = compat.make_mesh((8,), ("data",))
    s = choose_strategy(mesh, sasg_enabled=True)
    assert s.name == "flat"
    assert s.tp_axis is None
    assert s.num_workers == 8
    assert s.batch_axes == ("data",) and s.worker_axes == ("data",)


def test_sasg_disabled_gives_plain(mesh2d):
    s = choose_strategy(mesh2d, sasg_enabled=False)
    assert s.name == "plain"
    assert not s.uses_shard_map and s.upload_axes == ()
    assert s.grad_axes == ("data",)
    assert s.inner_dp is None


def test_plain_on_3d_mesh_shards_over_both_data_axes(mesh3d):
    s = choose_strategy(mesh3d, sasg_enabled=False)
    assert s.name == "plain"
    assert s.grad_axes == ("pod", "data")
    assert s.fsdp_axis == ("pod", "data")
    assert s.num_workers == 4  # DP degree, not SASG workers


def test_params_bytes_threshold_boundary(mesh3d):
    budget = 10_000
    tp = 2  # model axis size on mesh3d
    at_boundary = int(budget * tp / REPLICA_OVERHEAD)  # replica == budget
    assert worker_replication_fits(at_boundary, tp, budget)
    assert not worker_replication_fits(at_boundary + tp, tp, budget)

    s_fit = choose_strategy(
        mesh3d, sasg_enabled=True, params_bytes=at_boundary,
        replica_budget_bytes=budget,
    )
    assert s_fit.name == "hierarchical"  # boundary value still fits
    s_over = choose_strategy(
        mesh3d, sasg_enabled=True, params_bytes=at_boundary + tp,
        replica_budget_bytes=budget,
    )
    assert s_over.name == "plain"


@pytest.mark.skipif(
    compat.PARTIAL_AUTO_SHARD_MAP,
    reason="new JAX: the limit is probed live by the test_known_limits "
    "subprocess repro instead of an eager guard",
)
def test_hierarchical_fsdp_is_rejected_by_build(mesh3d):
    """On older JAX the documented limit is enforced eagerly: the compat
    full-manual degrade could not reproduce the partitioner CHECK and would
    silently un-shard the params instead."""
    from repro.configs import get_config
    from repro.core import sasg_config
    from repro.models import build
    from repro.optim import constant
    from repro.train import build_train_step

    cfg = get_config("llama3_8b").reduced()
    model = build(cfg)
    strat = Strategy(
        "hierarchical", ("pod",), ("pod", "data"), "data", "data", "model", 2
    )
    with pytest.raises(NotImplementedError, match="TP-only"):
        build_train_step(
            model, sasg_config(k_ratio=0.05, max_delay=5), mesh3d, strat,
            constant(0.05),
        )

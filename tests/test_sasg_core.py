"""Integration tests of the SASG engine: the four paper algorithms through
the real shard_map exchange on a 4x2 mesh, plus exactness reductions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (
    CompressorConfig,
    SASGConfig,
    SelectionConfig,
    build_exchange,
    lasg_config,
    sasg_config,
    sgd_config,
    sparse_config,
    update_global_state,
)
from repro.core.types import (
    add_worker_axis,
    strip_worker_axis,
    tree_sq_norm,
    tree_sub,
)

M = 4


def _make_problem(seed=0, n=64, din=16):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, din)).astype(np.float32)
    w_true = rng.normal(size=(din,)).astype(np.float32)
    Y = X @ w_true + 0.01 * rng.normal(size=n).astype(np.float32)
    params0 = {"w": jnp.zeros((din,)), "b": jnp.zeros(())}

    def loss_fn(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] + params["b"] - y) ** 2)

    return X, Y, params0, loss_fn


def _vag(loss_fn):
    return jax.value_and_grad(loss_fn)


def _run(cfg, mesh2d, T=50, lr=0.2, distinct_batches=False):
    X, Y, params0, loss_fn = _make_problem()
    ex = build_exchange(cfg, worker_axes=("data",), num_workers=M)
    vag = _vag(loss_fn)

    def worker(params, batch, wstate, gstate, key):
        wstate = strip_worker_axis(wstate)
        upd, wstate, info = ex.run(
            params, batch, wstate, gstate, jnp.float32(lr), key, vag
        )
        return upd, add_worker_axis(wstate), add_worker_axis(info)

    sm = jax.shard_map(
        worker, mesh=mesh2d,
        in_specs=(P(), (P("data"), P("data")), P("data"), P(), P()),
        out_specs=(P(), P("data"), P("data")),
        axis_names={"data"}, check_vma=False,
    )

    @jax.jit
    def step(params, batch, wstate, gstate, key):
        upd, wstate, info = sm(params, batch, wstate, gstate, key)
        new_params = jax.tree.map(lambda p, u: p - u.astype(p.dtype), params, upd)
        gstate = update_global_state(
            gstate, tree_sq_norm(tree_sub(new_params, params))
        )
        return new_params, wstate, gstate, info

    params = params0
    wstate = jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.asarray(x)[None], (M,) + jnp.asarray(x).shape),
        ex.init_worker(params),
    )
    gstate = ex.init_global()
    rng = np.random.default_rng(7)
    rounds = 0.0
    for t in range(T):
        if distinct_batches:
            idx = rng.integers(0, X.shape[0], size=X.shape[0])
            batch = (jnp.asarray(X[idx]), jnp.asarray(Y[idx]))
        else:
            batch = (jnp.asarray(X), jnp.asarray(Y))
        params, wstate, gstate, info = step(
            params, batch, wstate, gstate, jax.random.PRNGKey(t)
        )
        rounds += float(np.asarray(info.num_sent)[0])
    final_loss = float(loss_fn(params, (jnp.asarray(X), jnp.asarray(Y))))
    return params, final_loss, rounds


def _ref_sgd(T=50, lr=0.2):
    X, Y, params0, loss_fn = _make_problem()
    params = params0
    for _ in range(T):
        g = jax.grad(loss_fn)(params, (jnp.asarray(X), jnp.asarray(Y)))
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    return params


def test_sgd_preset_matches_reference(mesh2d):
    params, _, rounds = _run(sgd_config(), mesh2d)
    ref = _ref_sgd()
    dist = float(tree_sq_norm(tree_sub(params, ref))) ** 0.5
    assert dist < 1e-5
    assert rounds == 50 * M  # dense: every worker uploads every step


def test_sasg_k1_d1_reduces_to_sgd(mesh2d):
    """k=d and D=1 turns SASG exactly into distributed SGD."""
    cfg = sasg_config(k_ratio=1.0, max_delay=1)
    params, _, _ = _run(cfg, mesh2d)
    ref = _ref_sgd()
    assert float(tree_sq_norm(tree_sub(params, ref))) ** 0.5 < 1e-5


@pytest.mark.parametrize("preset", ["sparse", "lasg", "sasg"])
def test_presets_converge(preset, mesh2d):
    cfg = {
        "sparse": sparse_config(k_ratio=0.25),
        "lasg": lasg_config(max_delay=4),
        "sasg": sasg_config(k_ratio=0.25, max_delay=4),
    }[preset]
    _, loss, _ = _run(cfg, mesh2d, T=60)
    assert loss < 5e-3, f"{preset} failed to converge: {loss}"


def test_adaptive_methods_skip_rounds(mesh2d):
    _, _, rounds_lasg = _run(lasg_config(max_delay=4), mesh2d, T=60)
    assert rounds_lasg < 60 * M  # skipped at least some uploads


def test_sasg_converges_with_distinct_worker_batches(mesh2d):
    cfg = sasg_config(k_ratio=0.25, max_delay=5)
    _, loss, rounds = _run(cfg, mesh2d, T=80, distinct_batches=True)
    assert loss < 2e-2
    assert rounds <= 80 * M


def test_extra_compressors_converge(mesh2d):
    for name in ["qsgd", "signsgd_ef", "terngrad", "randk"]:
        cfg = SASGConfig(
            compressor=CompressorConfig(name=name, k_ratio=0.5),
            selection=SelectionConfig(enabled=False),
            name=name,
        )
        _, loss, _ = _run(cfg, mesh2d, T=80, lr=0.1)
        assert loss < 0.3, f"{name}: {loss}"
